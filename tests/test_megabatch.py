"""Megabatch workload execution (ISSUE 4).

Contracts under test:

  * bit-identity — `query_batch` / `run_workload(batch_size=B)` return
    the same matches, per-query counters (comm bytes, cross-shard rows,
    root-MBR skips, paths executed/skipped, match counts, cache hits)
    as the serial plane path, for B in {1, 3, 16}, including a
    mid-stream index replacement (migration) between a batch's dispatch
    and its consume;
  * pre-filtered readback — the in-kernel candidate-mask filter plus
    candidate-bearing-lane gather ships strictly fewer device->host
    bytes per query than the serial plane readback;
  * kernel == host — the leaf-only megabatch probe equals the host
    aR-tree traversal + mask filter for every (shard, length, query
    row, orientation);
  * readback id dtype — candidate row ids widen from int16 to int32
    exactly at the 2**15-row slab boundary (sentinel must stay
    representable);
  * satellites — plan-artifact LRU hits are counted in telemetry, and
    epoch-batched AW-ResNet updates reproduce the per-query schedule's
    admission decisions on a fixed trace.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.artree import build_artree, query_dominating
from repro.core.probeplane import (ClusterPlanes, build_tree_plane,
                                   pack_mask_bits)
from repro.kernels.dominance.ops import readback_id_dtype

_ENGINE = None

_COUNTERS = ("comm_bytes", "cross_shard_rows", "shards_skipped",
             "paths_executed", "paths_skipped", "n_matches", "cache_hits")


def _build(seed=3, n=220, machines=3, spm=2, steps=8):
    from repro.data.synthetic import nws_graph
    from repro.dist.cluster import DistributedGNNPE
    g = nws_graph(n, 5, 0.1, 6, seed=seed)
    return g, DistributedGNNPE.build(g, machines, shards_per_machine=spm,
                                     gnn_train_steps=steps, seed=seed)


def _engine():
    global _ENGINE
    if _ENGINE is None:
        g, eng = _build()
        eng.use_cache = False          # raw probe/join comparisons
        _ENGINE = (g, eng)
    return _ENGINE


# --------------------------------------------------------------------------- #
# tentpole: megabatch bit-identity + pre-filtered readback
# --------------------------------------------------------------------------- #


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000), b=st.sampled_from([1, 3, 16]))
def test_megabatch_bit_identical(seed, b):
    from repro.data.synthetic import make_workload
    g, eng = _engine()
    qs = make_workload(g, b, seed=seed, hot_fraction=0.4)
    serial = [eng.query(q, probe_mode="plane") for q in qs]
    batched = eng.query_batch(qs)
    assert len(batched) == len(qs)
    for (m_s, t_s), (m_b, t_b) in zip(serial, batched):
        assert m_s == m_b
        for f in _COUNTERS:
            assert getattr(t_s, f) == getattr(t_b, f), f
        assert t_b.batch_size == len(qs)
    # the batch shares ONE fused launch (+ one candidate gather),
    # attributed to the first query; readback is pre-filtered in-kernel
    # so it ships strictly fewer bytes than the per-query plane sorts
    launches = sum(t.probe_launches for _, t in batched)
    assert launches <= 2
    assert all(t.probe_launches == 0 for _, t in batched[1:])
    # the pre-filtered readback guarantee is a BATCH amortization claim:
    # at B=1 the fixed counts readback can rival a tiny plan's sort, so
    # the strict inequality is asserted for real batches (and, at bench
    # scale, by bench_e2e.workload_comparison / CI)
    d2h_serial = sum(t.probe_d2h_bytes for _, t in serial)
    d2h_mega = sum(t.probe_d2h_bytes for _, t in batched)
    if d2h_serial and b >= 3:
        assert d2h_mega < d2h_serial


def test_run_workload_megabatch_matches_serial_with_cache():
    """Twin engines, cache ON: the full workload loop (cache admission,
    hits, epoch-batched AW updates) is counter-identical serial vs
    megabatch — the cache sequence is replayed in stream order."""
    from repro.data.synthetic import make_workload
    g, e1 = _build(seed=7)
    _, e2 = _build(seed=7)
    qs = make_workload(g, 10, seed=11, hot_fraction=0.6)
    tels1 = e1.run_workload(qs, probe_mode="plane")
    tels2 = e2.run_workload(qs, probe_mode="plane", batch_size=4)
    for t1, t2 in zip(tels1, tels2):
        for f in _COUNTERS:
            assert getattr(t1, f) == getattr(t2, f), f
    assert e1.cache.hit_rate == e2.cache.hit_rate
    assert sorted(map(len, e1._slave_store.values())) \
        == sorted(map(len, e2._slave_store.values()))


def test_megabatch_mid_stream_invalidation():
    """A shard index replaced between dispatch and consume (migration /
    failover) must not be served from the dispatched launch: the batch
    re-runs on the serial plane path, bit-identically."""
    from repro.core.matching import ShardIndex
    from repro.core.artree import ARTree
    from repro.data.synthetic import make_workload
    g, eng = _engine()
    qs = make_workload(g, 4, seed=123, hot_fraction=0.0)
    want = [eng.query(q, probe_mode="plane") for q in qs]

    mb = eng._mb_dispatch(qs, "pescore")
    sid = min(eng.shards)
    sh = eng.shards[sid]
    # deserialize roundtrip: equal values, NEW tree identities (exactly
    # what hot_migrate leaves behind)
    sh.index = ShardIndex(
        embedded=sh.index.embedded,
        trees={l: ARTree.deserialize(t.serialize())
               for l, t in sh.index.trees.items()})
    got = eng._mb_consume(mb)
    for (m_s, t_s), (m_b, t_b) in zip(want, got):
        assert m_s == m_b
        for f in _COUNTERS:
            assert getattr(t_s, f) == getattr(t_b, f), f


# --------------------------------------------------------------------------- #
# kernel layer: leaf-only probe + packed-mask filter == host
# --------------------------------------------------------------------------- #


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 999), s=st.integers(1, 4))
def test_mega_probe_matches_host_traversal(seed, s):
    rng = np.random.default_rng(seed)
    n_d = 64
    dims = {1: 6, 2: 9}
    trees, verts = {}, {}
    for sid in range(s):
        for l, d in dims.items():
            n = int(rng.integers(1, 180))
            pts = rng.uniform(0, 1, (n, d)).astype(np.float32)
            trees[(sid, l)] = build_artree(pts)
            verts[(sid, l)] = rng.integers(0, n_d, (n, l + 1)).astype(
                np.int32)
    planes = ClusterPlanes()
    asm = planes.mega_assemble(
        [(sid, l, t) for (sid, l), t in trees.items()],
        lambda sid, l, t: verts[(sid, l)][t.perm])
    qmat, mask_rows, dense = {}, {}, []
    for l, d in dims.items():
        rows = rng.uniform(0, 1, (2, d)).astype(np.float32)
        mr = np.zeros((2, l + 1), np.int32)
        for r in range(2):
            for p in range(l + 1):
                mr[r, p] = len(dense)
                dense.append(rng.random(n_d) < 0.6)
        qmat[l], mask_rows[l] = rows, mr
    res = planes.mega_readback(planes.mega_dispatch(
        asm, qmat, mask_rows, pack_mask_bits(dense, n_d),
        use_pallas=False))
    for (sid, l), tree in trees.items():
        for r in range(2):
            hits, _ = query_dominating(tree, qmat[l][r])
            gv = verts[(sid, l)][hits]
            keep = np.ones(len(hits), bool)
            for p in range(l + 1):
                keep &= np.asarray(
                    [dense[mask_rows[l][r, p]][v] for v in gv[:, p]],
                    dtype=bool)
            got = res.candidates(l, sid, r)
            np.testing.assert_array_equal(np.sort(tree.perm[got]),
                                          np.sort(hits[keep]))


def test_mega_assembly_cached_and_invalidated():
    rng = np.random.default_rng(0)
    tree = build_artree(rng.uniform(0, 1, (40, 6)).astype(np.float32))
    verts = rng.integers(0, 32, (40, 2)).astype(np.int32)
    planes = ClusterPlanes()
    fn = lambda sid, l, t: verts[t.perm]
    a1 = planes.mega_assemble([(0, 1, tree)], fn)
    a2 = planes.mega_assemble([(0, 1, tree)], fn)
    assert a1 is a2 and planes.stats["mega_assemble_reuses"] == 1
    planes.invalidate(0)
    a3 = planes.mega_assemble([(0, 1, tree)], fn)
    assert a3 is not a1
    # identity backstop: a REPLACED tree yields a fresh assembly even
    # without an explicit invalidate
    tree2 = build_artree(rng.uniform(0, 1, (40, 6)).astype(np.float32))
    a4 = planes.mega_assemble([(0, 1, tree2)], fn)
    assert a4 is not a3
    assert a3.stale({(0, 1): tree2}) and not a4.stale({(0, 1): tree2})


# --------------------------------------------------------------------------- #
# satellite: candidate-id readback dtype boundary
# --------------------------------------------------------------------------- #


def test_readback_id_dtype_boundary():
    import jax.numpy as jnp
    assert readback_id_dtype(2 ** 15 - 1) is jnp.int16
    assert readback_id_dtype(2 ** 15) is jnp.int32
    assert readback_id_dtype(2 ** 15 + 256) is jnp.int32


@pytest.mark.slow
def test_plane_readback_over_int16_boundary():
    """A plane packed just OVER 2**15 rows must read back int32 ids —
    an int16 sentinel would alias row -32768 and corrupt candidates."""
    rng = np.random.default_rng(1)
    # total packed rows = leaves + internal levels; pick n so the
    # bucketed row count crosses 2**15
    n = 31_000
    pts = rng.uniform(0.3, 1.0, (n, 4)).astype(np.float32)
    tree = build_artree(pts)
    plane = build_tree_plane(tree)
    assert plane.rows.shape[0] >= 2 ** 15, "fixture must cross boundary"
    planes = ClusterPlanes()
    res = planes.probe([(0, 1, tree)], [(np.full(4, 0.25, np.float32), 1)],
                       use_pallas=False)
    assert res.cand_rows.dtype == np.int32
    want, _ = query_dominating(tree, np.full(4, 0.25, np.float32))
    np.testing.assert_array_equal(res.hits(0, 1, 0), want)


# --------------------------------------------------------------------------- #
# satellite: plan-artifact LRU + epoch-batched AW-ResNet updates
# --------------------------------------------------------------------------- #


def test_plan_artifact_lru_counts_hits():
    from repro.data.synthetic import random_walk_query
    g, eng = _engine()
    eng._plan_lru.clear()
    q = random_walk_query(g, 4, seed=77)
    _, t1 = eng.query(q, probe_mode="plane")
    _, t2 = eng.query(q, probe_mode="plane")
    assert t1.plan_cache_hits == 0 and t2.plan_cache_hits == 1
    q2 = random_walk_query(g, 5, seed=78)
    _, t3 = eng.query(q2, probe_mode="plane")
    assert t3.plan_cache_hits == 0
    # artifacts are reused, not recomputed: identical object identity
    key = eng._query_key(q)
    ent = eng._plan_lru[key]
    _, t4 = eng.query(q, probe_mode="plane")
    assert eng._plan_lru[key] is ent and t4.plan_cache_hits == 1


def test_aw_epoch_updates_match_per_query_admissions():
    """Epoch-batched Algorithm-5 training must (a) apply at most one
    update per epoch and (b) leave the same admission decisions as the
    per-query schedule on a fixed trace."""
    from repro.data.synthetic import make_workload
    g, e1 = _build(seed=13)
    _, e2 = _build(seed=13)
    qs = make_workload(g, 12, seed=21, hot_fraction=0.5)
    e1.run_workload(qs, cache_update_mode="per_query")
    e2.run_workload(qs, cache_update_mode="epoch")
    up1 = e1.aw.n_updates + e1.aw.n_rollbacks
    up2 = e2.aw.n_updates + e2.aw.n_rollbacks
    assert up2 <= 1 <= up1, (up1, up2)
    # same keys cached on the same slaves, same hit statistics
    for s1, s2 in zip(e1._slave_store.values(), e2._slave_store.values()):
        assert sorted(map(hash, s1)) == sorted(map(hash, s2))
    assert e1.cache.hit_rate == e2.cache.hit_rate
    # deferral is epoch-scoped: direct queries train immediately again
    assert not e1._defer_aw and not e2._defer_aw


def test_megabatch_retrace_bounded_across_batch_mixes():
    """Varying batch sizes/plan mixes must reuse compiled launches: the
    megabatch query axis buckets at MEGA_QUERY_BUCKET, not per shape."""
    from repro.data.synthetic import make_workload
    from repro.kernels.dominance.ops import megabatch_leaf_probe_jit
    g, eng = _engine()
    qs = make_workload(g, 24, seed=31, hot_fraction=0.3)
    before = megabatch_leaf_probe_jit._cache_size()
    # big batches land in the coarse MEGA_QUERY_BUCKET zone: row-count
    # jitter between batch mixes must collapse onto few compiled shapes
    for b in (16, 16, 15, 14, 16, 13):
        eng.query_batch(qs[:b])
    grew = megabatch_leaf_probe_jit._cache_size() - before
    assert grew <= 4, f"{grew} new compiles for 6 batch mixes"


def test_mask_operand_rows_bucketed_no_retrace():
    """The shared packed-mask operand has one bit row per (query,
    query-vertex), so its row count tracks the batch's total vertex
    count.  MASK_ROW_BUCKET padding must make two batches that differ
    ONLY in that total (same lengths, same lane buckets) reuse the
    compiled fused launch instead of retracing it."""
    from repro.data.synthetic import make_workload
    from repro.kernels.dominance.ops import megabatch_leaf_probe_jit
    g, eng = _engine()
    qs = make_workload(g, 12, seed=77, hot_fraction=0.0)
    q = min(qs, key=lambda x: x.n_vertices)
    eng.query_batch([q, q])                  # warm the compiled shape
    before = megabatch_leaf_probe_jit._cache_size()
    # one more copy of the SAME query: lengths and lane buckets are
    # unchanged, only the mask operand's raw row count differs
    eng.query_batch([q, q, q])
    grew = megabatch_leaf_probe_jit._cache_size() - before
    assert grew == 0, ("mask_bits row count retraced the fused launch "
                       "(rows must pad to MASK_ROW_BUCKET)")


def test_run_workload_batch_cache_update_mode_validation():
    g, eng = _engine()
    with pytest.raises(ValueError):
        eng.run_workload([], cache_update_mode="sometimes")
