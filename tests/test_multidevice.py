"""Multi-device shard_map path equivalence (subprocess, 8 fake devices).

The EP MoE block and the distributed top-k run under shard_map only when
a mesh is ambient; this file spawns a child interpreter with
--xla_force_host_platform_device_count=8 (the parent must stay at 1
device — smoke tests rely on it) and asserts the sharded paths equal the
single-device references bit-for-bit (f32).
"""

import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models.moe import MoEConfig, init_moe_params, _moe_ffn_local, moe_ffn
from repro.dist.sharding import set_rules, set_mesh, LM_RULES, RECSYS_RULES

mesh = jax.make_mesh((2, 4), ("data", "model"))

# --- MoE shard_map == local ------------------------------------------ #
cfg = MoEConfig(n_experts=8, top_k=2, d_ff_expert=16, n_shared=1,
                capacity_factor=8.0)
params = init_moe_params(jax.random.PRNGKey(0), 32, cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 24, 32), jnp.float32)
ref, _ = _moe_ffn_local(x, params, cfg)
set_rules(dict(LM_RULES, batch="data")); set_mesh(mesh)
with mesh:
    got, _ = jax.jit(lambda x, p: moe_ffn(x, p, cfg))(
        jax.device_put(x, NamedSharding(mesh, P("data", None, None))), params)
err = float(jnp.abs(got - ref).max())
assert err < 1e-5, f"moe mismatch {err}"

# gradients through the shard_map path
def loss(p):
    y, aux = moe_ffn(x, p, cfg)
    return jnp.sum(y ** 2) + aux
with mesh:
    g = jax.jit(jax.grad(loss))(params)
assert all(bool(jnp.isfinite(v).all()) for v in jax.tree.leaves(g))

# --- distributed top-k == argsort ------------------------------------ #
from repro.models.bert4rec import (Bert4RecConfig, init_bert4rec,
                                   bulk_topk_scores, serve_scores)
cfg2 = Bert4RecConfig(n_items=512, embed_dim=32, n_blocks=2, n_heads=2,
                      seq_len=16, d_ff=64, dtype=jnp.float32)
p2 = init_bert4rec(cfg2, jax.random.PRNGKey(0))
items = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 1, 512)
full = serve_scores(p2, cfg2, items)
want = jnp.take_along_axis(full, jnp.argsort(-full, axis=1)[:, :10], axis=1)
set_rules(dict(RECSYS_RULES, batch="data")); set_mesh(mesh)
with mesh:
    bv, bi = jax.jit(lambda p, i: bulk_topk_scores(p, cfg2, i, k=10,
                                                   chunk=64))(p2, items)
got2 = jnp.take_along_axis(full, bi, axis=1)
err2 = float(jnp.abs(got2 - want).max())
assert err2 == 0.0, f"topk mismatch {err2}"
print("MULTIDEVICE_OK")
"""


@pytest.mark.slow
def test_shardmap_paths_match_references():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert "MULTIDEVICE_OK" in out.stdout, \
        f"stdout:\n{out.stdout[-1500:]}\nstderr:\n{out.stderr[-1500:]}"
