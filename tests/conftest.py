"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests see 1 device."""

import os

import numpy as np
import pytest

from repro.core.graph import LabeledGraph


def pytest_addoption(parser):
    parser.addoption(
        "--run-gauntlet", action="store_true", default=False,
        help="run the full @gauntlet matrix (otherwise skipped; "
             "RUN_GAUNTLET=1 also enables it)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--run-gauntlet") or os.environ.get("RUN_GAUNTLET"):
        return
    skip = pytest.mark.skip(
        reason="gauntlet tier: pass --run-gauntlet (or RUN_GAUNTLET=1)")
    for item in items:
        if "gauntlet" in item.keywords:
            item.add_marker(skip)


@pytest.fixture(scope="session")
def small_graph() -> LabeledGraph:
    rng = np.random.default_rng(0)
    n, m = 150, 450
    edges = rng.integers(0, n, size=(m, 2))
    labels = rng.integers(0, 5, size=n)
    return LabeledGraph.from_edges(n, edges, labels)


@pytest.fixture(scope="session")
def nws_small():
    from repro.data.synthetic import nws_graph
    return nws_graph(400, 6, 0.1, 6, seed=0)


def vf2_oracle(data: LabeledGraph, query: LabeledGraph) -> set:
    from networkx.algorithms import isomorphism
    gm = isomorphism.GraphMatcher(
        data.to_networkx(), query.to_networkx(),
        node_match=lambda a, b: a["label"] == b["label"])
    out = set()
    for mp in gm.subgraph_monomorphisms_iter():
        inv = {v: k for k, v in mp.items()}
        out.add(tuple(inv[i] for i in range(query.n_vertices)))
    return out
