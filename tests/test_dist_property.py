"""Property-based tests for shard byte images + CRC integrity.

Beyond the seed assertions in test_distributed.py: serialize/deserialize
round-trips on arbitrary random graphs and partitions, byte-flip CRC
detection at arbitrary positions, and retransmission-loop termination
under heavy fault injection.  Uses hypothesis (or the repo's offline
fallback under src/hypothesis/).
"""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.artree import build_artree
from repro.core.embedding import EmbeddedPaths
from repro.core.graph import LabeledGraph
from repro.core.matching import ShardIndex
from repro.dist.migration import crc_transfer, hot_migrate
from repro.dist.partition import metis_like_partition
from repro.dist.shard import (Shard, apply_shard_delta, make_shards,
                              shard_crc32, shard_delta)


def _random_graph(n: int, m: int, n_labels: int, seed: int) -> LabeledGraph:
    rng = np.random.default_rng(seed)
    edges = rng.integers(0, n, size=(m, 2))
    labels = rng.integers(0, n_labels, size=n)
    return LabeledGraph.from_edges(n, edges, labels)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(12, 60), seed=st.integers(0, 99),
       parts=st.integers(2, 5))
def test_shard_roundtrip_arbitrary_graphs(n, seed, parts):
    g = _random_graph(n, 3 * n, 4, seed)
    p = metis_like_partition(g, parts, seed=seed)
    for s in make_shards(g, p.assignment, parts, halo_hops=2):
        s2 = Shard.deserialize(s.serialize())
        assert s2.sid == s.sid
        assert (s2.global_ids == s.global_ids).all()
        assert (s2.owned_mask == s.owned_mask).all()
        assert (s2.graph.labels == s.graph.labels).all()
        assert (s2.graph.edge_list == s.graph.edge_list).all()
        assert s2.index is None


@settings(max_examples=10, deadline=None)
@given(n_points=st.integers(1, 50), dim=st.integers(2, 8),
       seed=st.integers(0, 99))
def test_shard_roundtrip_preserves_index_bytes(n_points, dim, seed):
    """The aR-tree must survive the byte image bit-for-bit (the property
    hot migration relies on for non-interruptible queries)."""
    rng = np.random.default_rng(seed)
    g = _random_graph(10, 20, 3, seed)
    emb = rng.uniform(0, 1, (n_points, dim)).astype(np.float32)
    verts = rng.integers(0, 10, size=(n_points, 2)).astype(np.int32)
    index = ShardIndex(
        embedded={1: EmbeddedPaths(vertices=verts, embeddings=emb,
                                   length=1)},
        trees={1: build_artree(emb)})
    s = Shard(sid=0, graph=g, global_ids=np.arange(10, dtype=np.int64),
              owned_mask=np.ones(10, dtype=bool), index=index)
    s2 = Shard.deserialize(s.serialize())
    assert s2.index.trees[1].serialize() == index.trees[1].serialize()
    assert (s2.index.embedded[1].embeddings == emb).all()
    # re-serialization is byte-identical (canonical image)
    assert s2.serialize() == s.serialize()


@settings(max_examples=30, deadline=None)
@given(data=st.binary(min_size=1, max_size=512),
       pos_seed=st.integers(0, 10 ** 6),
       flip=st.integers(1, 255))
def test_crc32_detects_any_single_byte_flip(data, pos_seed, flip):
    crc = shard_crc32(data)
    bad = bytearray(data)
    pos = pos_seed % len(bad)
    bad[pos] ^= flip
    assert shard_crc32(bytes(bad)) != crc
    assert shard_crc32(data) == crc        # pure function


def _indexed_shard(n_points: int, dim: int, seed: int, sid: int = 0) -> Shard:
    rng = np.random.default_rng(seed)
    g = _random_graph(10, 20, 3, seed)
    embedded, trees = {}, {}
    for l in (1, 2):
        emb = rng.uniform(0, 1, (n_points, dim * (l + 1))).astype(np.float32)
        verts = rng.integers(0, 10, size=(n_points, l + 1)).astype(np.int32)
        embedded[l] = EmbeddedPaths(vertices=verts, embeddings=emb, length=l)
        trees[l] = build_artree(emb)
    return Shard(sid=sid, graph=g, global_ids=np.arange(10, dtype=np.int64),
                 owned_mask=np.ones(10, dtype=bool),
                 index=ShardIndex(embedded=embedded, trees=trees))


@settings(max_examples=10, deadline=None)
@given(n_points=st.integers(1, 40), dim=st.integers(2, 6),
       seed=st.integers(0, 99))
def test_shard_delta_roundtrip_carries_unchanged_lengths(n_points, dim, seed):
    """The streaming-update delta protocol: only changed components
    ship; unchanged lengths are carried BY IDENTITY (the property that
    keeps their resident probe planes warm), and the merged shard is
    byte-identical to the sender's re-indexed shard."""
    rng = np.random.default_rng(seed + 1)
    old = _indexed_shard(n_points, dim, seed)
    # new epoch: length 2 re-embedded, length 1 untouched
    emb2 = rng.uniform(0, 1, (n_points + 3, dim * 3)).astype(np.float32)
    verts2 = rng.integers(0, 10, size=(n_points + 3, 3)).astype(np.int32)
    new = Shard(sid=old.sid, graph=old.graph, global_ids=old.global_ids,
                owned_mask=old.owned_mask,
                index=ShardIndex(
                    embedded={1: old.index.embedded[1],
                              2: EmbeddedPaths(vertices=verts2,
                                               embeddings=emb2, length=2)},
                    trees={1: build_artree(old.index.embedded[1].embeddings),
                           2: build_artree(emb2)}))
    blob = shard_delta(old, new)
    assert len(blob) < len(new.serialize()), "delta must beat the full image"
    # ride the migration CRC machinery, then install
    tr = crc_transfer(blob, rng=np.random.default_rng(seed),
                      corrupt_prob=0.6)
    assert tr.ok
    merged = apply_shard_delta(old, tr.received)
    assert merged.serialize() == new.serialize()
    assert merged.index.trees[1] is old.index.trees[1], \
        "unchanged length must carry the old tree object (warm plane)"
    assert merged.index.embedded[1] is old.index.embedded[1]
    assert merged.index.trees[2] is not new.index.trees[2]


def test_shard_delta_rejects_wrong_sid():
    a = _indexed_shard(5, 3, seed=0, sid=0)
    b = _indexed_shard(5, 3, seed=0, sid=1)
    blob = shard_delta(a, a)
    import pytest
    with pytest.raises(ValueError):
        apply_shard_delta(b, blob)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 50))
def test_migration_terminates_under_heavy_corruption(seed):
    """Even at corrupt_prob=0.9 the retransmission loop converges and
    the delivered replica is intact."""
    g = _random_graph(20, 50, 3, seed)
    p = metis_like_partition(g, 2, seed=seed)
    shards = {s.sid: s for s in make_shards(g, p.assignment, 2)}
    routing = {0: 0, 1: 1}
    before = shards[0].serialize()
    res = hot_migrate(shards, [(0, 0, 1)], routing,
                      rng=np.random.default_rng(seed), corrupt_prob=0.9)
    assert res.crc_ok and routing[0] == 1
    assert shards[0].serialize() == before
