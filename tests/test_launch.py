"""Launch-layer units: mesh factory, HLO collective parser, rules."""

import jax

from repro.dist.sharding import (clear_rules, current_mesh, rules_ctx,
                                 set_mesh, spec_for)
from repro.launch.dryrun import _rules_for, collective_bytes
from repro.launch.mesh import HW, dp_axes_of


def test_collective_bytes_parser():
    hlo = """
  %ar = bf16[16,128]{1,0} all-reduce(%x), replica_groups={}
  %ag.1 = f32[4,256]{1,0} all-gather(%y), dimensions={0}
  %rs = (bf16[8,8]{1,0}, bf16[8,8]{1,0}) reduce-scatter(%a, %b)
  %a2a = s32[64]{0} all-to-all(%c)
  %cp-start = bf16[2,2]{1,0} collective-permute-start(%d)
  %cp-done = bf16[2,2]{1,0} collective-permute-done(%cp-start)
  %notacoll = f32[999]{0} add(%e, %f)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 16 * 128 * 2
    assert out["all-gather"] == 4 * 256 * 4
    assert out["reduce-scatter"] == 2 * 8 * 8 * 2
    assert out["all-to-all"] == 64 * 4
    assert out["collective-permute"] == 2 * 2 * 2     # -start once, -done not
    assert out["total"] == sum(out[k] for k in
                               ("all-reduce", "all-gather", "reduce-scatter",
                                "all-to-all", "collective-permute"))


def test_rules_context_and_spec():
    with rules_ctx({"batch": "data", "embed": None}):
        s = spec_for("batch", "embed")
        assert s == jax.sharding.PartitionSpec("data", None)
    assert spec_for("batch") == jax.sharding.PartitionSpec(None)


def test_rules_for_families():
    r = _rules_for("lm", ("data",))
    assert r["batch"] == "data"
    r2 = _rules_for("lm", ("pod", "data"))
    assert r2["batch"] == ("pod", "data")
    r3 = _rules_for("gnn", ("pod", "data"))
    assert r3["edges"] == ("pod", "data")


def test_mesh_helpers_and_hw():
    # mesh construction itself needs >= 256 devices; test the helpers
    class FakeMesh:
        axis_names = ("pod", "data", "model")
    assert dp_axes_of(FakeMesh()) == ("pod", "data")

    class FakeMesh2:
        axis_names = ("data", "model")
    assert dp_axes_of(FakeMesh2()) == ("data",)
    assert HW["peak_flops_bf16"] == 197e12
    assert HW["hbm_bw"] == 819e9


def test_set_mesh_roundtrip():
    class M:
        axis_names = ("data", "model")
        shape = {"data": 2, "model": 4}
    set_mesh(M())
    assert current_mesh() is not None
    clear_rules()
    assert current_mesh() is None
