"""Distributed runtime: partitioner, load balancing, migration, failover."""

import numpy as np
import pytest

from repro.core.graph import LabeledGraph
from repro.dist import loadbalance as lb
from repro.dist.migration import hot_migrate
from repro.dist.partition import (edge_cut, hash_partition,
                                  metis_like_partition, random_partition,
                                  size_balance)
from repro.dist.shard import Shard, make_shards, shard_crc32
from tests.conftest import vf2_oracle


def test_partitioner_cut_and_balance(nws_small):
    g = nws_small
    for parts in (8, 16):
        p = metis_like_partition(g, parts, seed=0)
        r = random_partition(g, parts)
        assert edge_cut(g, p) < edge_cut(g, r) * 0.85, \
            "metis-like should beat random by a margin"
        assert size_balance(p) <= 0.151
        assert np.bincount(p.assignment, minlength=parts).min() > 0


def test_hash_partition_deterministic(nws_small):
    a = hash_partition(nws_small, 8).assignment
    b = hash_partition(nws_small, 8).assignment
    assert (a == b).all()


def test_shards_cover_all_paths(nws_small):
    """Canonical-owner rule: every edge owned by exactly one shard."""
    g = nws_small
    p = metis_like_partition(g, 6, seed=0)
    shards = make_shards(g, p.assignment, 6, halo_hops=2)
    owners = np.zeros(g.n_edges, dtype=np.int64)
    edge_id = {(int(u), int(v)): i for i, (u, v) in enumerate(g.edge_list)}
    for s in shards:
        el = s.graph.edge_list
        gu = s.global_ids[el[:, 0]]
        gv = s.global_ids[el[:, 1]]
        local_canon_is_owned = s.owned_mask[
            np.where(s.global_ids[el[:, 0]] <= s.global_ids[el[:, 1]],
                     el[:, 0], el[:, 1])]
        for (a, b), owned in zip(np.stack([np.minimum(gu, gv),
                                           np.maximum(gu, gv)], 1),
                                 local_canon_is_owned):
            if owned:
                owners[edge_id[(int(a), int(b))]] += 1
    assert (owners == 1).all(), "each edge indexed by exactly one shard"


def test_load_formula_and_trigger():
    t = lb.MachineTelemetry(0, [0, 1], {0: 0.5, 1: 0.25}, {0: 10, 1: 0},
                            {0: 0.1, 1: 0.05}, {0: 0.9, 1: 0.9})
    load = lb.machine_load(t, comm_max=10.0)
    assert abs(load - (0.4 * 0.75 + 0.3 * 1.0 + 0.3 * 0.15)) < 1e-9
    assert lb.cluster_sigma(np.array([1.0, 0.0])) == pytest.approx(0.5)
    assert lb.alpha_decay(0.0) == pytest.approx(0.7)
    assert lb.alpha_decay(60.0) == 0.0
    assert lb.alpha_decay(1e9) == 0.0


def test_plan_migrations_moves_from_overloaded():
    tele = [
        lb.MachineTelemetry(0, [0, 1, 2], {0: 0.9, 1: 0.8, 2: 0.7},
                            {0: 5, 1: 4, 2: 3}, {0: .1, 1: .1, 2: .1}, {}, 0.5),
        lb.MachineTelemetry(1, [3], {3: 0.01}, {3: 0}, {3: 0.01}, {}, 0.0),
    ]
    plan = lb.plan_migrations(
        tele, corr_fn=lambda s, k: 0.05, wlabel_fn=lambda s, k: 0.5,
        shard_sizes={i: 1.0 for i in range(4)})
    assert plan.trigger
    assert plan.moves, "overload must produce at least one move"
    for sid, src, tgt in plan.moves:
        assert src == 0 and tgt == 1


def _mini_cluster(nws_small, n_machines=3, spm=3):
    from repro.dist.cluster import DistributedGNNPE
    return DistributedGNNPE.build(nws_small, n_machines,
                                  shards_per_machine=spm,
                                  gnn_train_steps=15, seed=0)


@pytest.fixture(scope="module")
def engine(nws_small):
    return _mini_cluster(nws_small)


def test_distributed_exactness(engine, nws_small):
    from repro.data.synthetic import make_workload
    for q in make_workload(nws_small, 4, seed=3):
        matches, tel = engine.query(q)
        assert set(matches) == vf2_oracle(nws_small, q)
        assert tel.latency_ms > 0


def test_migration_crc_and_consistency(engine):
    shards = engine.shards
    routing = dict(engine.routing)
    sid = next(iter(shards))
    src = routing[sid]
    tgt = (src + 1) % len(engine.specs)
    before = shards[sid].index.trees[1].serialize()
    res = hot_migrate(shards, [(sid, src, tgt)], routing,
                      rng=np.random.default_rng(0))
    assert res.crc_ok and routing[sid] == tgt
    assert shards[sid].index.trees[1].serialize() == before, \
        "aR-tree must be byte-identical after migration"


def test_migration_fault_injection_retransmits(engine):
    shards = dict(engine.shards)
    routing = dict(engine.routing)
    sid = next(iter(shards))
    total_retrans = 0
    for seed in range(6):   # corruption is stochastic; sample several runs
        res = hot_migrate(shards, [(sid, routing[sid],
                                    (routing[sid] + 1) % 3)], routing,
                          rng=np.random.default_rng(seed), corrupt_prob=0.6)
        assert res.crc_ok
        total_retrans += res.retransmissions
    assert total_retrans > 0, "corruption should force retransmissions"


def test_hot_migrate_skips_stale_and_duplicate_moves(engine):
    """Regression: a move list naming the same shard twice (planner
    double-emit), or a sid that no longer exists (removed between plan
    and execute), raised KeyError mid-batch and left `routing`
    half-applied with no record.  Stale moves are skipped and reported;
    valid moves in the same batch still execute."""
    shards = dict(engine.shards)
    routing = dict(engine.routing)
    sids = sorted(shards)
    a, b = sids[0], sids[1]
    n_m = len(engine.specs)
    src_a, src_b = routing[a], routing[b]
    tgt = (src_a + 1) % n_m                 # guaranteed != src_a
    ghost = max(sids) + 999
    moves = [
        (ghost, 0, 1),                      # unknown shard: skip
        (a, src_a, tgt),                    # valid: executes
        (a, src_a, (src_a + 2) % n_m),      # duplicate: src now stale
        (b, (src_b + 1) % n_m, tgt),        # stale source: skip
    ]
    res = hot_migrate(shards, moves, routing,
                      rng=np.random.default_rng(0))
    assert res.migrated == [a]
    assert routing[a] == tgt, "the valid move must still land"
    assert routing[b] == src_b, "stale-source move must not touch routing"
    skipped = {sid: reason for sid, reason in res.skipped}
    assert set(skipped) == {ghost, a, b}
    assert skipped[ghost] == "unknown shard"
    assert res.crc_ok


def test_crc32_detects_flip():
    blob = b"hello world" * 100
    crc = shard_crc32(blob)
    bad = bytearray(blob)
    bad[7] ^= 0xFF
    assert shard_crc32(bytes(bad)) != crc


def test_shard_serialize_roundtrip(engine):
    sid = next(iter(engine.shards))
    s = engine.shards[sid]
    s2 = Shard.deserialize(s.serialize())
    assert s2.sid == s.sid
    assert (s2.global_ids == s.global_ids).all()
    assert s2.graph.n_edges == s.graph.n_edges


def test_worker_failover_exactness(nws_small):
    from repro.data.synthetic import make_workload
    from repro.train.elastic import WorkerFailover
    eng = _mini_cluster(nws_small)
    fo = WorkerFailover(eng)
    dead = fo.fail_machine(1)
    assert dead and all(eng.routing[s] != 1 for s in dead)
    qs = make_workload(nws_small, 3, seed=9)
    assert fo.verify_exactness(qs, lambda q: vf2_oracle(nws_small, q))


def test_straggler_mitigation():
    from repro.train.elastic import StragglerMitigator
    sm = StragglerMitigator(deadline_x=2.0)
    lat = {0: 10.0, 1: 11.0, 2: 9.0, 3: 200.0}
    eff = sm.probe_with_speculation(lat)
    assert eff[3] < 200.0 and sm.reissued == 1
    assert sm.recovered_ms > 150


def test_rebalance_clock_uses_epoch_virtual_seconds(nws_small, monkeypatch):
    """Regression: run_workload used to feed the per-query counter to
    `plan_migrations` as seconds_since_migration, so the anti-thrash
    boost suppressed legitimate rebalances for ~60 *queries*.  The clock
    is virtual epoch seconds: one epoch = EPOCH_VIRTUAL_S, and a
    sigma-violating epoch right after the window must rebalance at the
    un-boosted threshold."""
    from repro.data.synthetic import make_workload
    from repro.dist import cluster as cluster_mod
    eng = _mini_cluster(nws_small)
    qs = make_workload(nws_small, 4, seed=1)
    seen = []

    def spy(telemetry, **kw):
        # record the clock value and never migrate, so the window
        # elapses undisturbed
        seen.append(kw["seconds_since_migration"])
        return lb.MigrationPlan(False, [], 0.0, 0.0)

    monkeypatch.setattr(cluster_mod.lb, "plan_migrations", spy)
    # simulate "a migration just happened" on both clock generations
    eng._last_migration_epoch = getattr(eng, "_epoch", 0)
    eng._qclock = 0.0
    eng._last_migration_t = 0.0            # pre-fix attribute (ignored now)
    n_epochs = int(lb.ALPHA_WINDOW_S / cluster_mod.EPOCH_VIRTUAL_S)
    for _ in range(n_epochs):
        eng.run_workload(qs, rebalance=True)
    # after the full window the boost must have decayed to zero — the
    # next trigger comparison runs at the plain SIGMA_THRESHOLD
    assert seen[-1] >= lb.ALPHA_WINDOW_S - 1e-9
    assert lb.alpha_decay(seen[-1]) == 0.0


def test_dead_machine_never_homes_cache(nws_small):
    """Regression: a query that probes no shard used to register its
    cached result on slave 0 even when machine 0 was dead."""
    from repro.train.elastic import WorkerFailover
    eng = _mini_cluster(nws_small)
    WorkerFailover(eng).fail_machine(0)
    # star query whose center needs a degree no data vertex has: the
    # label/degree filter kills it up front, so no shard is ever probed
    # and rows_by_machine stays empty
    k = int(nws_small.degrees.max()) + 1
    edges = np.array([[0, i] for i in range(1, k + 1)])
    q = LabeledGraph.from_edges(k + 1, edges,
                                np.zeros(k + 1, dtype=np.int64))
    matches, tel = eng.query(q)
    assert matches == [] and tel.cross_shard_rows == 0
    key = eng._query_key(q)
    home = eng.cache.location[key]
    assert home != 0, "cache must never home onto a dead machine"
    assert home not in eng.dead_machines
    assert key in eng._slave_store[home]
    assert key not in eng._slave_store[0]


def test_dead_machine_cache_entry_never_serves(nws_small):
    """Regression: a result homed on a machine that later died kept
    serving from its (unreachable) slave tiers — and `peek` said True,
    so megabatch dispatch skipped probe packing for a query the consume
    step should re-execute.  Peek and access are dead-aware in
    lockstep: the query re-executes exactly, with no cache hit."""
    from repro.data.synthetic import make_workload
    eng = _mini_cluster(nws_small)
    q = make_workload(nws_small, 1, seed=17)[0]
    m0, _ = eng.query(q)
    key = eng._query_key(q)
    home = eng.cache.location[key]
    # evict any master-cache copy so only the (dying) slave tiers hold
    # the result, then drop the machine without purging its stores
    eng.cache.master._drop(key)
    eng.dead_machines.add(home)
    assert not eng._cache_peek(key), \
        "peek must not promise a result only a dead machine holds"
    # megabatch path first (before anything re-homes the result):
    # dispatch must pack probes and consume must re-execute exactly
    (m1, t1), = eng.query_batch([q])
    assert t1.cache_hits == 0, "dead machine's entry must not serve"
    assert m1 == m0
    # the re-executed result re-homed onto a LIVE machine: serves again
    m2, t2 = eng.query(q)
    assert m2 == m0 and t2.cache_hits == 1
    assert eng.cache.location[key] not in eng.dead_machines


def test_all_machines_dead_skips_cache_admission(nws_small):
    """With no live machine there is nowhere to home a result: admission
    must be skipped entirely, not routed to a dead default."""
    eng = _mini_cluster(nws_small)
    eng.dead_machines.update(range(len(eng.specs)))
    k = int(nws_small.degrees.max()) + 1
    edges = np.array([[0, i] for i in range(1, k + 1)])
    q = LabeledGraph.from_edges(k + 1, edges,
                                np.zeros(k + 1, dtype=np.int64))
    matches, _ = eng.query(q)
    assert matches == []
    key = eng._query_key(q)
    assert key not in eng.cache.location
    assert all(key not in store for store in eng._slave_store.values())


def test_pe_fit_labels_deterministic(nws_small):
    """Regression: PE-score labels used wall-clock probe timings, so two
    identical builds fitted different models.  Labels now come from
    deterministic probe statistics (rows + leaves tested)."""
    e1 = _mini_cluster(nws_small)
    e2 = _mini_cluster(nws_small)
    assert e1.pe_model.gbdt is not None
    np.testing.assert_array_equal(e1.pe_model.gbdt.value,
                                  e2.pe_model.gbdt.value)
    np.testing.assert_array_equal(e1.pe_model.gbdt.threshold,
                                  e2.pe_model.gbdt.threshold)
    np.testing.assert_array_equal(e1.pe_model.gbdt.feature,
                                  e2.pe_model.gbdt.feature)
    assert e1.pe_fit_report["n_probes"] == e2.pe_fit_report["n_probes"]


def test_load_balancing_reduces_sigma(nws_small):
    """Skewed workload -> trigger -> migrations -> lower sigma."""
    from repro.data.synthetic import make_workload
    eng = _mini_cluster(nws_small)
    qs = make_workload(nws_small, 12, seed=5, hot_fraction=0.8, n_hot=2)
    eng.run_workload(qs, rebalance=False)
    sigma_before = eng.load_sigma()
    eng.run_workload(qs, rebalance=True)
    if eng.migrations:
        assert eng.load_sigma() <= sigma_before + 1e-6
