"""reprolint self-tests.

Three layers: (1) each rule fires on its bad fixture at the exact
lines — and at nothing else — while the good twin scans silent;
(2) the suppression and baseline mechanisms behave (inline disable
silences, stale baseline entries fail); (3) the repo itself is clean:
``src tests benchmarks`` produce zero non-baselined findings against
the checked-in ``analysis/baseline.json``.  Layer (3) is the tier-1
gate the CI ``reprolint`` job mirrors.
"""

import dataclasses
import json
import os
import subprocess
import sys
from pathlib import Path

from repro.analysis import baseline as baseline_mod
from repro.analysis.context import FileContext
from repro.analysis.registry import all_rules
from repro.analysis.runner import RunResult, find_root, run_paths

FIXTURES = Path(__file__).resolve().parent / "analysis_fixtures"
ROOT = find_root()


def _raw_hits(ctx):
    hits = []
    for rule in all_rules():
        if not rule.applies_to(ctx.rel):
            continue
        hits.extend((f.rule, f.line) for f in rule.check(ctx))
    return sorted(hits)


def scan_fixture(fname, rel=None):
    """All-rule scan of one fixture, honouring inline suppressions.

    ``rel`` re-parents the parsed file to a synthetic repo path so
    path-scoped rules (RPR002-4 guard src/repro/dist/) see it.
    """
    ctx = FileContext.parse(FIXTURES / fname, ROOT)
    assert ctx is not None, f"fixture {fname} failed to parse"
    if rel is not None:
        ctx = dataclasses.replace(ctx, rel=rel)
    silenced = ctx.suppressed_lines()
    return sorted(h for h in _raw_hits(ctx)
                  if h[0] not in silenced.get(h[1], set()))


# -- per-rule fixtures ----------------------------------------------------

def test_rpr001_fires_on_unbucketed_boundary_operand():
    assert scan_fixture("rpr001_bad.py") == [("RPR001", 8)]


def test_rpr001_silent_when_rows_bucketed():
    assert scan_fixture("rpr001_good.py") == []


def test_rpr002_fires_on_epoch_unsafe_cache_key():
    rel = "src/repro/dist/rpr002_bad.py"
    assert scan_fixture("rpr002_bad.py", rel) == [("RPR002", 7)]


def test_rpr002_silent_when_key_flows_from_query_key():
    rel = "src/repro/dist/rpr002_good.py"
    assert scan_fixture("rpr002_good.py", rel) == []


def test_rpr003_fires_on_uncrcd_decode():
    rel = "src/repro/dist/rpr003_bad.py"
    assert scan_fixture("rpr003_bad.py", rel) == [("RPR003", 6)]


def test_rpr003_silent_when_blob_is_crc_verified():
    rel = "src/repro/dist/rpr003_good.py"
    assert scan_fixture("rpr003_good.py", rel) == []


def test_rpr004_fires_on_wall_clock_and_global_rng():
    rel = "src/repro/dist/rpr004_bad.py"
    assert scan_fixture("rpr004_bad.py", rel) == [("RPR004", 8),
                                                  ("RPR004", 9)]


def test_rpr004_silent_on_virtual_clock_and_seeded_rng():
    rel = "src/repro/dist/rpr004_good.py"
    assert scan_fixture("rpr004_good.py", rel) == []


def test_rpr004_inline_suppression_absorbs_the_diagnostic():
    # the good fixture DOES contain a wall-clock call — prove the rule
    # sees it and the inline `# reprolint: disable` is what silences it
    rel = "src/repro/dist/rpr004_good.py"
    ctx = FileContext.parse(FIXTURES / "rpr004_good.py", ROOT)
    ctx = dataclasses.replace(ctx, rel=rel)
    assert ("RPR004", 14) in _raw_hits(ctx)
    assert scan_fixture("rpr004_good.py", rel) == []


def test_rpr005_fires_on_forced_device_value_in_dispatch():
    assert scan_fixture("rpr005_bad.py") == [("RPR005", 7)]


def test_rpr005_silent_when_forcing_moves_to_consume():
    assert scan_fixture("rpr005_good.py") == []


def test_rpr006_fires_on_contract_violations():
    # line 7: declared bucket 192 not a multiple of block 128
    # line 18: pad +inf where the table declares -inf
    # line 20: mask operand built uint8, table declares uint32
    assert scan_fixture("rpr006_bad.py") == [("RPR006", 7),
                                             ("RPR006", 18),
                                             ("RPR006", 20)]


def test_rpr006_silent_on_conforming_declaration_and_call():
    assert scan_fixture("rpr006_good.py") == []


def test_rpr007_fires_on_non_plan_rng_in_hook_handlers():
    # line 7: engine rng drawn inside a fire()-ing function
    # line 12: fresh generator constructed in a hook handler
    # line 14: draw from that non-plan generator
    rel = "src/repro/dist/rpr007_bad.py"
    assert scan_fixture("rpr007_bad.py", rel) == [("RPR007", 7),
                                                  ("RPR007", 12),
                                                  ("RPR007", 14)]


def test_rpr007_silent_on_plan_rng_and_fire_free_engine_rng():
    rel = "src/repro/dist/rpr007_good.py"
    assert scan_fixture("rpr007_good.py", rel) == []


def test_rpr008_fires_on_index_subscripts_in_serving_functions():
    # line 7: query() reads self.shards[sid] around the router
    # line 11: _consume_query() reads self.routing[sid] directly
    rel = "src/repro/dist/rpr008_bad.py"
    assert scan_fixture("rpr008_bad.py", rel) == [("RPR008", 7),
                                                  ("RPR008", 11)]


def test_rpr008_silent_on_router_resolution_and_owner_functions():
    rel = "src/repro/dist/rpr008_good.py"
    assert scan_fixture("rpr008_good.py", rel) == []


def test_rpr009_fires_on_link_primitives_and_replica_store_reads():
    # line 9: direct crc_transfer call bypasses the engine transport
    # line 13: direct _link_faults call (raw fault-model access)
    # lines 17/20: Load-context reads of replicas.copies[...]
    rel = "src/repro/dist/rpr009_bad.py"
    assert scan_fixture("rpr009_bad.py", rel) == [("RPR009", 9),
                                                  ("RPR009", 13),
                                                  ("RPR009", 17),
                                                  ("RPR009", 20)]


def test_rpr009_silent_on_transport_calls_and_owner_mutations():
    rel = "src/repro/dist/rpr009_good.py"
    assert scan_fixture("rpr009_good.py", rel) == []


# -- baseline mechanism ---------------------------------------------------

def test_stale_baseline_entry_fails_the_run():
    entry = {"rule": "RPR004", "path": "src/nowhere.py",
             "content": "t = time.time()", "reason": "gone"}
    kept, baselined, stale = baseline_mod.apply([], [entry], {})
    assert stale == [entry]
    res = RunResult(findings=[], baselined=[], suppressed=[],
                    stale_baseline=stale, n_files=0)
    assert not res.ok


def test_checked_in_baseline_entries_all_match():
    res = run_paths(["src", "tests", "benchmarks"], root=ROOT)
    assert not res.stale_baseline, (
        "stale analysis/baseline.json entries: "
        + json.dumps(res.stale_baseline, indent=2))


# -- repo self-scan (the tier-1 gate) -------------------------------------

def test_repo_is_clean():
    res = run_paths(["src", "tests", "benchmarks"], root=ROOT)
    rendered = "\n".join(f.render() for f in res.findings)
    assert res.ok, f"reprolint findings:\n{rendered}"


def test_cli_json_output():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis",
         "--paths", "src/repro/analysis", "--no-baseline",
         "--format", "json"],
        capture_output=True, text=True, env=env, cwd=ROOT)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    data = json.loads(proc.stdout)
    assert data["ok"] is True
    assert data["findings"] == []
