"""Innovations 2 & 3: caching subsystem + PE-score plan ranking."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.cache.awresnet import AWResNet, initial_weights_from_warmup
from repro.cache.features import FeatureTracker, dynamic_window
from repro.cache.policy import (LFUCache, LRUCache, TwoLevelCache, ValueCache,
                                dynamic_trigger, protected_degree_threshold)
from repro.core.pescore import (PEScoreModel, adaptive_tree_count,
                                fit_gbdt)


# --------------------------------------------------------------------------- #
# features
# --------------------------------------------------------------------------- #
def test_dynamic_window_tiers():
    assert dynamic_window(25) == 30.0
    assert dynamic_window(10) == 60.0
    assert dynamic_window(2) == 120.0


def test_feature_tracker_ranges():
    tr = FeatureTracker()
    for t in range(50):
        # p0 accessed every step (genuinely hot); others round-robin
        sigs = ["p0", f"p{1 + t % 4}"]
        tr.record_query(float(t), sigs, {s: t % 2 == 0 for s in sigs})
    for s in [f"p{i}" for i in range(5)]:
        f = tr.features(s)
        assert all(0.0 <= x <= 1.0 for x in f), f
    f_hot = tr.features("p0")
    assert f_hot[0] >= max(tr.features(f"p{i}")[0] for i in range(1, 5)) - 1e-9


def test_feature_decay_monotone():
    tr = FeatureTracker()
    tr.record_query(0.0, ["x"], {"x": True})
    f0 = tr.features("x")
    tr.now = 600.0         # 2*tau later
    f1 = tr.features("x")
    assert f1[0] < f0[0] and f1[3] <= f0[3]


# --------------------------------------------------------------------------- #
# AW-ResNet (Algorithms 2 & 5)
# --------------------------------------------------------------------------- #
def test_algorithm2_initial_weights():
    rng = np.random.default_rng(0)
    f = rng.uniform(0, 1, (100, 4))
    f[:, 2] *= 10          # high-variance feature
    w = initial_weights_from_warmup(f)
    assert w.shape == (4,) and abs(w.sum() - 1.0) < 1e-9
    assert w[2] == w.max()
    # zero variance -> equal weights
    w0 = initial_weights_from_warmup(np.ones((10, 4)))
    assert np.allclose(w0, 0.25)


def test_awresnet_weights_sum_to_one():
    m = AWResNet(seed=0)
    w = m.weights(np.random.default_rng(0).uniform(0, 1, (7, 4)))
    assert w.shape == (7, 4)
    assert np.allclose(w.sum(axis=1), 1.0, atol=1e-5)


def test_algorithm5_rollback_gate():
    m = AWResNet(seed=0)
    rng = np.random.default_rng(0)
    for i in range(120):
        f = rng.uniform(0, 1, 4)
        m.observe(f, float(f[0] > 0.5))        # hits correlate with f1
    assert m.should_train(hit_rate=0.5)
    m.train_once(hit_rate=0.5, latency_ms=5.0)
    assert m.n_updates + m.n_rollbacks == 1    # decision recorded either way


# --------------------------------------------------------------------------- #
# eviction policy (Algorithm 4)
# --------------------------------------------------------------------------- #
def test_dynamic_trigger_tiers():
    assert dynamic_trigger(0.9, 5.0) == 0.95
    assert dynamic_trigger(0.7, 15.0) == 0.90
    assert dynamic_trigger(0.4, 30.0) == 0.80


def test_protected_degree_threshold():
    assert protected_degree_threshold(np.array([1, 2, 3])) == 10.0
    d = np.concatenate([np.full(95, 10), np.full(5, 100)])
    assert protected_degree_threshold(d) >= 10.0


@settings(max_examples=20, deadline=None)
@given(cap=st.integers(4, 50), n=st.integers(1, 200), seed=st.integers(0, 9))
def test_value_cache_capacity_invariant(cap, n, seed):
    rng = np.random.default_rng(seed)
    c = ValueCache(capacity=cap)
    for i in range(n):
        c.put(i, i, float(rng.uniform()), avg_deg=float(rng.uniform(0, 20)),
              hit_rate=0.5, latency_ms=30.0)
        assert len(c.store) <= cap


def test_value_cache_beats_lru_on_skewed_workload():
    """The paper's claim: value-aware caching beats LRU on skewed access."""
    rng = np.random.default_rng(0)
    n_paths, cap = 400, 40
    # zipf popularity + scan pollution (LRU's weakness)
    hot = rng.zipf(1.5, 4000) % 50
    scan = np.arange(4000) % n_paths
    stream = np.where(rng.random(4000) < 0.5, hot, scan)
    vc = ValueCache(capacity=cap)
    lru = LRUCache(capacity=cap)
    freq = np.zeros(n_paths)
    for k in stream:
        k = int(k)
        freq[k] += 1
        lru.get(k)
        lru.put(k, k)
        if vc.get(k) is None:
            vc.put(k, k, value=float(freq[k]), avg_deg=1.0,
                   hit_rate=vc.hit_rate, latency_ms=30.0)
    assert vc.hit_rate > lru.hit_rate, (vc.hit_rate, lru.hit_rate)


@settings(max_examples=10, deadline=None)
@given(cap=st.integers(1, 8), extra=st.integers(1, 6),
       orphan=st.integers(0, 3))
def test_value_cache_evict_survives_diverged_maps(cap, extra, orphan):
    """Regression: the hard-capacity loop keyed on `self.value` while
    checking `len(self.store)` — with the maps diverged (store keys
    missing from value, value keys missing from store) it either raised
    on an empty min() or spun forever dropping keys that never shrank
    the store.  Eviction must operate on the store alone."""
    vc = ValueCache(capacity=cap)
    for i in range(cap + extra):
        vc.store[f"s{i}"] = i               # store-only keys: no V entry
    for i in range(orphan):
        vc.value[f"orphan{i}"] = 0.9        # value-only keys: no store entry
    n = vc.maybe_evict(hit_rate=1.0, latency_ms=1.0)   # t_up=0.95
    assert len(vc.store) <= vc.capacity
    assert n >= extra


def test_value_cache_evict_single_source_of_truth_counts():
    """Orphan value keys must not inflate eviction counts (they are not
    cached entries) — only store drops count."""
    vc = ValueCache(capacity=2)
    vc.put("a", 1, value=0.9, avg_deg=100.0)
    vc.value["ghost"] = 0.01                # diverged: no store entry
    vc.put("b", 2, value=0.8, avg_deg=100.0)
    vc.put("c", 3, value=0.7, avg_deg=100.0)
    assert len(vc.store) <= 2
    assert "ghost" not in vc.store


def test_two_level_hit_rate_counts_memory_serves():
    """Regression: a slave_memory serve was counted as a miss while
    `access` reported it found (and the engine flags it cache_hits=1).
    The documented definition: hit_rate = fraction of accesses that
    returned data from ANY tier; only not_found is a miss."""
    tl = TwoLevelCache(n_slaves=1, master_capacity=2, slave_capacity=2)
    tl.register("a", 0)
    slave_data = {0: {"a": 42}}
    r = tl.access("a", slave_data)          # slave_memory serve
    assert r.source == "slave_memory" and r.data == 42
    assert tl.hit_rate == 1.0
    r2 = tl.access("nope", slave_data)      # genuine miss
    assert r2.source == "not_found"
    assert tl.hit_rate == pytest.approx(0.5)
    tl.admit("a", 42, value=1.0, avg_deg=1.0, slave_id=0, hit_rate=0.5,
             latency_ms=5.0)
    assert tl.access("a", slave_data).source == "master_cache"
    assert tl.hit_rate == pytest.approx(2 / 3)


def test_two_level_peek_and_access_skip_dead_slaves():
    """Regression: `peek` said True for a key homed on a dead machine
    while the authoritative path could not serve it — dispatch would
    skip packing for a query consume then re-executes.  Both sides now
    take the dead set and stay in lockstep (master cache still serves:
    it lives on the master node)."""
    tl = TwoLevelCache(n_slaves=2, master_capacity=2, slave_capacity=2)
    tl.register("a", 1)
    slave_data = {1: {"a": 7}}
    assert tl.peek("a", slave_data)
    assert tl.access("a", slave_data).data == 7
    dead = {1}
    assert not tl.peek("a", slave_data, dead=dead)
    r = tl.access("a", slave_data, dead=dead)
    assert r.data is None and r.source == "not_found"
    # master-cache entries survive the slave's death
    tl.master.put("a", 7, value=1.0)
    assert tl.peek("a", slave_data, dead=dead)
    assert tl.access("a", slave_data, dead=dead).source == "master_cache"


def test_two_level_access_priority():
    tl = TwoLevelCache(n_slaves=2, master_capacity=4, slave_capacity=2)
    tl.register("a", 0)
    slave_data = {0: {"a": 123}}
    r = tl.access("a", slave_data)
    assert r.source == "slave_memory" and r.data == 123 and r.cross_node
    tl.admit("a", 123, value=1.0, avg_deg=1.0, slave_id=0, hit_rate=0.5,
             latency_ms=5.0)
    r2 = tl.access("a", slave_data)
    assert r2.source == "master_cache" and not r2.cross_node
    assert r2.latency_ms < r.latency_ms
    r3 = tl.access("zzz", {})
    assert r3.source == "not_found"


def test_lfu_cache():
    c = LFUCache(2)
    c.put("a", 1)
    c.put("b", 2)
    c.get("a")
    c.put("c", 3)          # evicts b (least frequent)
    assert c.get("a") is not None
    assert c.get("b") is None


# --------------------------------------------------------------------------- #
# PE-score (Innovation 3)
# --------------------------------------------------------------------------- #
def test_adaptive_tree_count():
    assert adaptive_tree_count(0) == 50
    assert adaptive_tree_count(100_000) == 150
    assert adaptive_tree_count(10_000_000) == 300


def test_gbdt_fits_nonlinear():
    rng = np.random.default_rng(0)
    x = rng.uniform(-1, 1, (500, 4)).astype(np.float32)
    y = np.where(x[:, 0] > 0, 2.0, -1.0) + 0.5 * x[:, 1]
    m = fit_gbdt(x, y, n_trees=40, depth=3)
    pred = m.predict(x)
    base = np.mean((y - y.mean()) ** 2)
    assert np.mean((y - pred) ** 2) < 0.2 * base


def test_gbdt_jax_matches_numpy_walk():
    rng = np.random.default_rng(1)
    x = rng.uniform(0, 1, (200, 3)).astype(np.float32)
    y = x[:, 0] * 3 + x[:, 1]
    m = fit_gbdt(x, y, n_trees=10, depth=2)
    p1 = m.predict(x)
    p2 = m.predict(x)       # determinism
    assert np.allclose(p1, p2)


def test_pescore_label():
    s = PEScoreModel.label_pe_score(n_valid=10, n_total=100,
                                    filter_time_ms=2.0)
    assert s == pytest.approx(0.9 / 2.0)


def test_plan_ranking_reduces_cross_shard_bytes(nws_small):
    """Algorithm 6 vs degree-order: fewer cross-shard candidate rows."""
    from repro.data.synthetic import make_workload
    from repro.dist.cluster import DistributedGNNPE
    eng = DistributedGNNPE.build(nws_small, 3, shards_per_machine=3,
                                 gnn_train_steps=15, seed=0)
    qs = make_workload(nws_small, 6, seed=11)
    eng.use_cache = False
    bytes_pe = sum(eng.query(q, plan_mode="pescore")[1].comm_bytes
                   for q in qs)
    bytes_deg = sum(eng.query(q, plan_mode="degree")[1].comm_bytes
                    for q in qs)
    assert bytes_pe <= bytes_deg * 1.05, (bytes_pe, bytes_deg)


def test_plan_dependency_resolution(nws_small):
    """Paths sharing vertices must run shorter-first (Algorithm 6 step 4)."""
    from repro.core.paths import paths_of_query
    from repro.core.plan import rank_query_plan
    from repro.data.synthetic import random_walk_query
    q = random_walk_query(nws_small, 5, seed=0)
    model = PEScoreModel()            # untrained -> constant scores, fine
    plan = rank_query_plan(q, model, max_path_length=2)
    tables = paths_of_query(q, 2)
    seen_verts: list[tuple[set, int]] = []
    for ti, r in plan.order:
        vs = set(tables[ti].vertices[r].tolist())
        l = tables[ti].length
        for vs2, l2 in seen_verts:
            if vs & vs2:
                assert l >= l2, "longer path scheduled before shorter overlap"
        seen_verts.append((vs, l))
