"""Chaos harness + crash-consistent replication (ISSUE 8).

The contract under test: NO fault schedule may ever produce a wrong
answer.  Every query under an arbitrary seeded interleaving of machine
crashes, corrupted transfers, link timeouts and torn delta images is
either bit-identical to the fault-free run or raises a typed
``ClusterUnavailableError`` on genuine quorum loss — never a wrong or
partial result, never torn state.

Layers:

  * FaultPlan mechanics — seeded determinism, visit anchoring, replay;
  * link-level faults through ``crc_transfer`` — retransmission,
    exponential backoff, bounded budget, typed timeout;
  * transactional aborts — ``hot_migrate`` and ``apply_updates`` left
    fully-old by a mid-transaction fault, and safely retryable;
  * replication — anti-affine placement, promotion exactness, quorum
    loss (last machine / last copy) regressions;
  * cache failover audit — nothing cache-homed on a dead machine,
    property-tested over failure/query interleavings;
  * the chaos oracle — 22 seeded fault schedules (hand-built + random)
    over a workload script that spans host/device/plane/megabatch
    probe modes, streaming updates and rebalance epochs, each checked
    bit-identical to the fault-free baseline.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.data.synthetic import nws_graph
from repro.dist.chaos import (CORRUPT, CRASH, HOOK_BATCH,
                              HOOK_MIGRATE_PREPARE, HOOK_QUERY, HOOK_READ,
                              HOOK_REBALANCE, HOOK_TRANSFER,
                              HOOK_UPDATE_COMMIT, HOOK_UPDATE_STAGE, SLOW,
                              TIMEOUT, TORN, ClusterUnavailableError,
                              FaultPlan, FaultSpec, TransferTimeoutError,
                              Unavailable, default_script, random_fault_plan,
                              run_script, script_queries)
from repro.dist.cluster import DistributedGNNPE
from repro.dist.migration import (BACKOFF_BASE_MS, MAX_RETRIES, crc_transfer,
                                  hot_migrate, migrate_with_retry)
from repro.dist.router import (BROWNOUT, DEGRADED, HEALTHY,
                               AdmissionRejected, QueryBudget,
                               QueryDeadlineExceeded)

N_MACHINES = 3


@pytest.fixture(scope="module")
def graph():
    return nws_graph(80, 6, 0.1, 5, seed=0)


@pytest.fixture(scope="module")
def ref(graph):
    """One full build (partitioner + GNN training) for the module; every
    other engine injects its assignment/params — same indexes, cheap."""
    return DistributedGNNPE.build(graph, N_MACHINES, shards_per_machine=2,
                                  gnn_train_steps=4, seed=0)


def _engine(graph, ref, k=0, failover="promote"):
    return DistributedGNNPE.build(graph, N_MACHINES, shards_per_machine=2,
                                  gnn_train_steps=4, seed=0,
                                  assignment=ref.assignment,
                                  params=ref.params, replication=k,
                                  failover_mode=failover)


@pytest.fixture(scope="module")
def script(graph):
    return default_script(graph, seed=0)


@pytest.fixture(scope="module")
def baseline(graph, ref, script):
    """Fault-free answers for the module script — replication consumes
    no engine rng (corrupt_prob=0 transfers draw nothing), so one k=0
    baseline is the bit-identity target for every k."""
    answers, outcome = run_script(_engine(graph, ref), script)
    assert outcome == "completed"
    assert len(answers) == script_queries(script)
    return answers


# ------------------------------------------------------------------------- #
# FaultPlan mechanics
# ------------------------------------------------------------------------- #

def test_fault_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec(kind="meteor", hook=HOOK_QUERY)
    with pytest.raises(ValueError):
        FaultSpec(kind=CRASH, hook="cluster.nowhere")
    with pytest.raises(ValueError):
        FaultSpec(kind=CRASH, hook=HOOK_TRANSFER)   # engine hooks only
    with pytest.raises(ValueError):
        FaultSpec(kind=TORN, hook=HOOK_QUERY)       # link hooks only
    with pytest.raises(ValueError):
        FaultSpec(kind=TORN, hook=HOOK_TRANSFER, at=0)


def test_fault_plan_visit_anchoring_and_replay():
    plan = FaultPlan([FaultSpec(kind=TORN, hook=HOOK_TRANSFER, at=2,
                                times=2)], seed=7)
    assert [len(plan.fire(HOOK_TRANSFER)) for _ in range(4)] == [0, 1, 1, 0]
    assert plan.visits(HOOK_TRANSFER) == 4
    assert [(h, n) for h, n, _ in plan.fired] == [(HOOK_TRANSFER, 2),
                                                  (HOOK_TRANSFER, 3)]
    # replay rewinds both the visit counters and the rng stream
    twin = plan.replay()
    assert twin.visits(HOOK_TRANSFER) == 0
    assert twin.faults == plan.faults
    assert twin.rng.integers(1 << 30) == FaultPlan(
        plan.faults, seed=7).rng.integers(1 << 30)


def test_random_fault_plan_is_seed_deterministic():
    a = random_fault_plan(3, n_faults=6, n_machines=N_MACHINES)
    b = random_fault_plan(3, n_faults=6, n_machines=N_MACHINES)
    assert a.faults == b.faults
    assert a.faults != random_fault_plan(4, n_faults=6,
                                         n_machines=N_MACHINES).faults
    # crash count respects the availability bound
    crashes = [f for f in a.faults if f.kind == CRASH]
    assert len(crashes) <= N_MACHINES - 1


# ------------------------------------------------------------------------- #
# link faults through crc_transfer (satellite: rng is required)
# ------------------------------------------------------------------------- #

def test_crc_transfer_requires_engine_rng():
    # the silent module-global rng fallback is gone: every call site
    # must thread its own generator or corruption runs are unseeded
    with pytest.raises(TypeError):
        crc_transfer(b"payload")


def test_torn_and_corrupt_attempts_are_retransmitted():
    blob = bytes(range(200)) * 3
    plan = FaultPlan([FaultSpec(kind=TORN, hook=HOOK_TRANSFER, at=1),
                      FaultSpec(kind=CORRUPT, hook=HOOK_TRANSFER, at=2)],
                     seed=1)
    tr = crc_transfer(blob, rng=np.random.default_rng(0), chaos=plan)
    assert tr.ok and tr.received == blob
    assert tr.retransmissions == 2


def test_timeout_budget_exhaustion_is_typed_with_backoff():
    blob = b"x" * 1000
    plan = FaultPlan([FaultSpec(kind=TIMEOUT, hook=HOOK_TRANSFER, at=1,
                                times=4)], seed=0)
    with pytest.raises(TransferTimeoutError) as exc:
        crc_transfer(blob, rng=np.random.default_rng(0), chaos=plan,
                     max_retries=3)
    assert exc.value.attempts == 4
    # three successful attempts' worth of backoff is strictly cheaper
    # than four failures (exponential growth, not linear)
    assert exc.value.virtual_ms > 4 * BACKOFF_BASE_MS
    # one fewer fault and the final attempt delivers clean
    tr = crc_transfer(blob, rng=np.random.default_rng(0),
                      chaos=FaultPlan([FaultSpec(kind=TIMEOUT,
                                                 hook=HOOK_TRANSFER, at=1,
                                                 times=3)], seed=0),
                      max_retries=3)
    assert tr.ok and tr.received == blob


def test_virtual_deadline_raises_before_retry_budget():
    blob = b"y" * 1000
    plan = FaultPlan([FaultSpec(kind=TIMEOUT, hook=HOOK_TRANSFER, at=1,
                                times=MAX_RETRIES + 1)], seed=0)
    with pytest.raises(TransferTimeoutError) as exc:
        crc_transfer(blob, rng=np.random.default_rng(0), chaos=plan,
                     timeout_ms=12.0)
    assert exc.value.attempts < MAX_RETRIES + 1
    assert exc.value.virtual_ms > 12.0


def test_slow_fault_charges_virtual_time_without_data_loss():
    blob = b"z" * 100_000
    clean = crc_transfer(blob, rng=np.random.default_rng(0))
    plan = FaultPlan([FaultSpec(kind=SLOW, hook=HOOK_TRANSFER, at=1,
                                factor=8.0)], seed=0)
    slow = crc_transfer(blob, rng=np.random.default_rng(0), chaos=plan)
    assert slow.ok and slow.received == blob and slow.retransmissions == 0
    assert slow.virtual_ms > clean.virtual_ms


# ------------------------------------------------------------------------- #
# transactional aborts: fully-old, retryable
# ------------------------------------------------------------------------- #

def test_hot_migrate_aborts_fully_old_on_transfer_timeout(graph, ref):
    eng = _engine(graph, ref)
    shards_before = dict(eng.shards)
    routing_before = dict(eng.routing)
    moves = [(sid, mk, (mk + 1) % N_MACHINES)
             for sid, mk in sorted(eng.routing.items())]
    plan = FaultPlan([FaultSpec(kind=TIMEOUT, hook=HOOK_TRANSFER, at=2,
                                times=MAX_RETRIES + 1)], seed=0)
    with pytest.raises(TransferTimeoutError):
        hot_migrate(eng.shards, moves, eng.routing,
                    rng=np.random.default_rng(0), chaos=plan)
    # the first move's transfer SUCCEEDED before the second timed out —
    # yet nothing committed: identical objects, identical routing
    assert eng.shards == shards_before
    assert eng.routing == routing_before


def test_hot_migrate_prepare_hook_fault_aborts_the_batch(graph, ref):
    eng = _engine(graph, ref)
    routing_before = dict(eng.routing)
    moves = [(sid, mk, (mk + 1) % N_MACHINES)
             for sid, mk in sorted(eng.routing.items())]
    plan = FaultPlan([FaultSpec(kind=TORN, hook=HOOK_MIGRATE_PREPARE,
                                at=2)], seed=0)
    with pytest.raises(TransferTimeoutError):
        hot_migrate(eng.shards, moves, eng.routing,
                    rng=np.random.default_rng(0), chaos=plan)
    assert eng.routing == routing_before


def test_apply_updates_aborts_fully_old_and_retries_bit_identical(
        graph, ref, script):
    delta = next(op[1] for op in script if op[0] == "update")
    probe = next(op for op in script if op[0] == "query")
    clean = _engine(graph, ref)
    clean.apply_updates(delta, refit_pe=False)
    want, _ = clean.query(probe[1], probe_mode=probe[2])

    eng = _engine(graph, ref, k=1)
    epoch_before = eng._data_epoch
    pre, _ = eng.query(probe[1], probe_mode=probe[2])
    plan = FaultPlan([FaultSpec(kind=TIMEOUT, hook=HOOK_TRANSFER, at=1,
                                times=MAX_RETRIES + 1)], seed=0)
    eng.set_fault_plan(plan)
    with pytest.raises(TransferTimeoutError):
        eng.apply_updates(delta, refit_pe=False)
    # fully-old: epoch unmoved, answers unmoved, audit clean
    assert eng.aborted_transactions == 1
    assert eng._data_epoch == epoch_before
    assert eng.consistency_audit() == []
    again, _ = eng.query(probe[1], probe_mode=probe[2])
    assert again == pre
    # the faults are spent: the retry commits, bit-identical to clean
    eng.apply_updates(delta, refit_pe=False)
    eng.set_fault_plan(None)
    got, _ = eng.query(probe[1], probe_mode=probe[2])
    assert got == want
    assert eng.consistency_audit() == []


# ------------------------------------------------------------------------- #
# replication: placement, promotion exactness, quorum loss
# ------------------------------------------------------------------------- #

def test_replica_placement_is_anti_affine_and_full(graph, ref):
    eng = _engine(graph, ref, k=2)
    for sid, primary in eng.routing.items():
        holders = eng.replicas.holders(sid, eng.dead_machines)
        assert len(holders) == 2
        assert primary not in holders
    assert eng.consistency_audit() == []


def test_promotion_failover_preserves_exactness(graph, ref, script):
    queries = [op for op in script if op[0] == "query"]
    eng = _engine(graph, ref, k=1)
    want = [eng.query(q, probe_mode=m)[0] for _, q, m in queries]
    victims = eng.handle_machine_failure(1)
    assert victims                       # machine 1 owned shards
    assert eng.replicas.promotions >= len(victims)
    assert eng.consistency_audit() == []
    assert all(mk != 1 for mk in eng.routing.values())
    got = [eng.query(q, probe_mode=m)[0] for _, q, m in queries]
    assert got == want
    # redundancy was restored best-effort on the survivors
    for sid, primary in eng.routing.items():
        assert eng.replicas.holders(sid, eng.dead_machines) == \
            [m for m in range(N_MACHINES)
             if m != primary and m != 1][:1]


def test_double_failure_with_k1_promotes_twice(graph, ref, script):
    _, q, m = next(op for op in script if op[0] == "query")
    eng = _engine(graph, ref, k=1)
    want, _ = eng.query(q, probe_mode=m)
    eng.handle_machine_failure(0)
    eng.handle_machine_failure(2)        # re-replication after kill #1
    assert eng.consistency_audit() == []  # makes this survivable
    assert set(eng.routing.values()) == {1}
    got, _ = eng.query(q, probe_mode=m)
    assert got == want


def test_last_live_machine_raises_typed_unavailable(graph, ref, script):
    """Regression (satellite): killing the last live machine used to
    die with a bare min()/KeyError deep in the balancer — it must be a
    typed ClusterUnavailableError, and the engine must latch."""
    _, q, m = next(op for op in script if op[0] == "query")
    eng = _engine(graph, ref)            # k=0: legacy byte-image path
    eng.handle_machine_failure(0)
    eng.handle_machine_failure(1)
    with pytest.raises(ClusterUnavailableError) as exc:
        eng.handle_machine_failure(2)
    assert exc.value.reason == "no-survivors"
    assert exc.value.machines == (0, 1, 2)       # structured, not prose
    # latched: every later operation raises the same typed error
    for attempt in (lambda: eng.query(q, probe_mode=m),
                    lambda: eng.query_batch([q]),
                    lambda: eng.run_workload([q])):
        with pytest.raises(ClusterUnavailableError):
            attempt()


def test_losing_a_shards_last_copy_raises_no_live_copy(graph, ref):
    eng = _engine(graph, ref, k=1)
    victim_sid = min(sid for sid, mk in eng.routing.items() if mk == 0)
    eng.replicas.drop_shard(victim_sid)  # simulate the standby rotting
    with pytest.raises(ClusterUnavailableError) as exc:
        eng.handle_machine_failure(0)
    assert exc.value.reason == "no-live-copy"
    # structured loss: WHICH shards and WHICH machines, machine-readable
    assert exc.value.sids == (victim_sid,)
    assert exc.value.machines == (0,)
    assert eng._unavailable == "no-live-copy"


def test_dead_machine_is_idempotent_and_out_of_range_is_noop(graph, ref):
    eng = _engine(graph, ref, k=1)
    assert eng.handle_machine_failure(99) == []
    first = eng.handle_machine_failure(1)
    assert first
    assert eng.handle_machine_failure(1) == []   # already dead


# ------------------------------------------------------------------------- #
# cache failover audit (satellite): nothing homed on a corpse
# ------------------------------------------------------------------------- #

@given(ops=st.lists(st.integers(min_value=0, max_value=4),
                    min_size=2, max_size=7))
@settings(max_examples=10, deadline=None)
def test_cache_never_homes_on_dead_machine(graph, ref, script, ops):
    """Interleave queries (warming both cache levels) with machine
    kills: after EVERY op the cache audit must be clean — no slave
    ValueCache entry, slave-memory result or master location pointer
    may survive on a dead machine."""
    queries = [op for op in script if op[0] == "query"]
    eng = _engine(graph, ref, k=1)
    for tok in ops:
        try:
            if tok <= 2:                       # kill machine 0/1/2
                eng.handle_machine_failure(tok)
            else:                              # run (and re-run) queries
                _, q, m = queries[tok - 3]
                eng.query(q, probe_mode=m)
                eng.query(q, probe_mode=m)     # second hit exercises reuse
        except ClusterUnavailableError:
            break
        assert eng.cache_audit() == []
        assert eng.consistency_audit() == []


# ------------------------------------------------------------------------- #
# the chaos oracle: >= 20 seeded schedules, bit-identical or typed
# ------------------------------------------------------------------------- #

def _hand_schedules():
    """Targeted schedules pinning every hook point — including the two
    the issue calls out by name: mid-megabatch (HOOK_BATCH) and
    mid-apply_updates (HOOK_UPDATE_STAGE / HOOK_UPDATE_COMMIT)."""
    mk = FaultSpec
    return [
        ("crash-query", [mk(kind=CRASH, hook=HOOK_QUERY, at=2,
                            machine=1)]),
        ("crash-query-unpinned", [mk(kind=CRASH, hook=HOOK_QUERY, at=5)]),
        ("crash-mid-megabatch", [mk(kind=CRASH, hook=HOOK_BATCH, at=1,
                                    machine=2)]),
        ("crash-mid-update-stage", [mk(kind=CRASH, hook=HOOK_UPDATE_STAGE,
                                       at=1, machine=0)]),
        ("crash-pre-update-commit", [mk(kind=CRASH,
                                        hook=HOOK_UPDATE_COMMIT, at=1,
                                        machine=2)]),
        ("crash-rebalance", [mk(kind=CRASH, hook=HOOK_REBALANCE, at=1,
                                machine=1)]),
        ("link-storm", [mk(kind=TORN, hook=HOOK_TRANSFER, at=1, times=2),
                        mk(kind=CORRUPT, hook=HOOK_TRANSFER, at=4),
                        mk(kind=TIMEOUT, hook=HOOK_TRANSFER, at=6),
                        mk(kind=SLOW, hook=HOOK_TRANSFER, at=8,
                           factor=9.0)]),
        ("update-timeout-retry", [mk(kind=TIMEOUT, hook=HOOK_TRANSFER,
                                     at=1, times=MAX_RETRIES + 1)]),
        ("crash-plus-dirty-links", [mk(kind=CRASH, hook=HOOK_QUERY, at=3,
                                       machine=0),
                                    mk(kind=TORN, hook=HOOK_TRANSFER,
                                       at=1, times=3),
                                    mk(kind=CORRUPT, hook=HOOK_TRANSFER,
                                       at=5, times=2)]),
        ("slow-everything", [mk(kind=SLOW, hook=HOOK_TRANSFER, at=1,
                                times=10, factor=8.0)]),
    ]


CHAOS_CASES = ([(name, FaultPlan(faults, seed=i), 1 + i % 2)
                for i, (name, faults) in enumerate(_hand_schedules())]
               + [(f"random-{s}",
                   random_fault_plan(s, n_faults=5, n_machines=N_MACHINES),
                   1 + s % 2)
                  for s in range(12)])
assert len(CHAOS_CASES) >= 20


@pytest.mark.parametrize("name,plan,k", CHAOS_CASES,
                         ids=[c[0] for c in CHAOS_CASES])
def test_chaos_oracle_bit_identical_to_fault_free(graph, ref, script,
                                                  baseline, name, plan, k):
    """Schedules bounded to < N_MACHINES crashes can never lose quorum
    under replication: the outcome must be completion with answers
    bit-identical to the fault-free baseline — full match lists for
    query/batch ops, the deterministic n_matches counter for epochs."""
    eng = _engine(graph, ref, k=k)
    answers, outcome = run_script(eng, script, plan=plan.replay())
    assert outcome == "completed", f"{name}: {outcome}"
    assert answers == baseline, f"{name}: answers diverged"


def test_chaos_oracle_run_script_consumes_the_plan(graph, ref, script,
                                                   baseline):
    # sanity for the harness itself: a pinned crash really fires, and
    # run_script detaches the plan afterwards
    plan = FaultPlan([FaultSpec(kind=CRASH, hook=HOOK_QUERY, at=2,
                                machine=1)], seed=0)
    eng = _engine(graph, ref, k=1)
    answers, outcome = run_script(eng, script, plan=plan)
    assert outcome == "completed"
    assert answers == baseline
    assert [(f.kind, f.machine) for _, _, f in plan.fired] == [(CRASH, 1)]
    assert 1 in eng.dead_machines
    assert eng.chaos is None


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_chaos_oracle_quorum_loss_is_typed_with_identical_prefix(
        graph, ref, script, baseline, seed):
    """All-machines-crash schedules: the run must end in a typed
    unavailability (reason machine-checkable), with every answer
    produced BEFORE the loss bit-identical to the baseline prefix."""
    plan = FaultPlan([FaultSpec(kind=CRASH, hook=HOOK_QUERY, at=2 + i,
                                machine=(seed + i) % N_MACHINES)
                     for i in range(N_MACHINES)], seed=seed)
    eng = _engine(graph, ref)            # k=0: no standby to promote
    answers, outcome = run_script(eng, script, plan=plan)
    assert outcome.startswith("unavailable@"), outcome
    assert eng._unavailable in ("no-survivors", "no-live-copy")
    assert answers == baseline[:len(answers)]


# ------------------------------------------------------------------------- #
# degraded-mode serving (ISSUE 9): replica-read routing, budgets, brownout
# ------------------------------------------------------------------------- #

def test_rebalance_epoch_survives_a_timed_out_step(graph, ref):
    """Regression (satellite): one stubborn link used to abort the WHOLE
    rebalance epoch — a single TransferTimeoutError from `hot_migrate`
    dropped every remaining planned move on the floor.  Per-step
    transactions retry the move with backoff, then skip-and-report it
    while the rest of the epoch proceeds."""
    eng = _engine(graph, ref)
    sids = sorted(eng.routing)
    moves = [(sid, eng.routing[sid], (eng.routing[sid] + 1) % N_MACHINES)
             for sid in sids[:3]]
    # the first move's link is dead for every transfer attempt of every
    # per-step retry; the later moves' links are clean
    dead_attempts = (MAX_RETRIES + 1) * 3
    plan = FaultPlan([FaultSpec(kind=TIMEOUT, hook=HOOK_TRANSFER, at=1,
                                times=dead_attempts)], seed=3)
    res = migrate_with_retry(eng.shards, moves, eng.routing, rng=eng._rng,
                             chaos=plan, step_retries=2)
    assert res.migrated == [m[0] for m in moves[1:]], \
        "the rest of the epoch must proceed past the dead step"
    assert [s for s, _ in res.skipped] == [moves[0][0]]
    assert "transfer timeout" in res.skipped[0][1]
    assert res.timeouts == 3                     # every abort was counted
    assert eng.routing[moves[0][0]] == moves[0][1]   # aborted fully-old
    for sid, _, tgt in moves[1:]:
        assert eng.routing[sid] == tgt


def test_route_mode_serves_standbys_before_promotion(graph, ref, script):
    """Tentpole: with failover_mode="route" a crash promotes NOTHING —
    reads route to standby replicas immediately, answers stay
    bit-identical, comm bytes land on the machine that served, and
    recover() later folds the promotions in and un-latches HEALTHY."""
    queries = [op for op in script if op[0] == "query"]
    twin = _engine(graph, ref, k=2)
    twin.use_cache = False
    want = [twin.query(q, probe_mode=m)[0] for _, q, m in queries]
    eng = _engine(graph, ref, k=2, failover="route")
    eng.use_cache = False
    victims = eng.handle_machine_failure(1)
    assert victims
    assert eng.replicas.promotions == 0          # promotion deferred
    assert all(eng.routing[sid] == 1 for sid in victims)
    assert eng.consistency_audit() == []         # degraded, not torn
    assert eng.router.state() == DEGRADED
    assert sorted(eng.router.degraded_sids()) == victims
    assert eng.router.lost_sids() == []
    for (_, q, m), w in zip(queries, want):
        got, tel = eng.query(q, probe_mode=m)
        assert got == w
        assert tel.outcome.health == DEGRADED
    assert eng.router.standby_reads > 0
    # comm/CPU attribution: nothing lands on the corpse
    tele = eng._machine_telemetry()
    assert all(t.machine_id != 1 for t in tele)
    assert eng._cpu and all(v >= 0 for v in eng._cpu.values())
    # recovery folds the deferred promotions in: HEALTHY, no corpse
    # left in the routing table, answers unchanged
    rec = eng.recover()
    assert sorted(rec["promoted"]) == victims and rec["lost"] == []
    assert rec["state"] == HEALTHY
    assert all(mk != 1 for mk in eng.routing.values())
    assert eng.replicas.promotions == len(victims)
    assert [eng.query(q, probe_mode=m)[0] for _, q, m in queries] == want
    assert eng.consistency_audit() == []


def test_route_mode_megabatch_serves_degraded_shards(graph, ref, script):
    """The fused megabatch path under deferred failover: assembled slabs
    whose identity is still clean serve from the flight (attributed to
    the standby), and answers match the fault-free serial run."""
    queries = [op[1] for op in script if op[0] == "query"][:3]
    twin = _engine(graph, ref, k=2)
    twin.use_cache = False
    want = [twin.query(q, probe_mode="plane")[0] for q in queries]
    eng = _engine(graph, ref, k=2, failover="route")
    eng.use_cache = False
    eng.handle_machine_failure(0)
    got = eng.query_batch(queries)
    assert [m for m, _ in got] == want
    assert any(t.outcome.served_degraded for _, t in got)
    assert eng.replicas.promotions == 0


def test_megabatch_per_shard_fallback_on_stale_slab(graph, ref, script):
    """A shard index replaced between dispatch and consume (migration)
    orphans ONLY that shard's fused rows: the consume step re-probes it
    per shard on the routed live copy instead of re-running the whole
    batch serially.  Matches and comm bytes stay bit-identical."""
    queries = [op[1] for op in script if op[0] == "query"][:3]
    twin = _engine(graph, ref, k=1)
    twin.use_cache = False
    want = [(twin.query(q, probe_mode="plane")[0],
             twin.query(q, probe_mode="plane")[1].comm_bytes)
            for q in queries]
    eng = _engine(graph, ref, k=1)
    eng.use_cache = False
    mb = eng._mb_dispatch(queries, "pescore")
    sid = sorted(eng.routing)[0]
    src = eng.routing[sid]
    hot_migrate(eng.shards, [(sid, src, (src + 1) % N_MACHINES)],
                eng.routing, rng=eng._rng)
    out = eng._mb_consume(mb)
    assert [m for m, _ in out] == [w for w, _ in want]
    assert [t.comm_bytes for _, t in out] == [c for _, c in want]


def test_routed_read_retries_with_backoff_under_read_faults(graph, ref,
                                                            script):
    """CORRUPT read attempts are caught by the CRC discipline and
    retried on the same route with crc_transfer-style backoff; the
    stall is typed into the outcome and folded into latency."""
    _, q, m = next(op for op in script if op[0] == "query")
    twin = _engine(graph, ref, k=2)
    want, _ = twin.query(q, probe_mode=m)
    eng = _engine(graph, ref, k=2, failover="route")
    plan = FaultPlan([FaultSpec(kind=CORRUPT, hook=HOOK_READ, at=1,
                                times=2)], seed=1)
    eng.set_fault_plan(plan)
    got, tel = eng.query(q, probe_mode=m)
    eng.set_fault_plan(None)
    assert got == want
    assert tel.outcome.retries == 2
    assert tel.outcome.hedges == 0
    assert tel.outcome.stall_ms > 0
    assert tel.latency_ms >= tel.outcome.stall_ms
    # fault-free twin of the same engine state pays ZERO stall
    got2, tel2 = _engine(graph, ref, k=2, failover="route").query(
        q, probe_mode=m)
    assert got2 == want and tel2.outcome.stall_ms == 0.0


def test_routed_read_hedges_to_next_holder(graph, ref, script):
    """TIMEOUT attempts past hedge_after_ms re-issue the read to the
    NEXT live holder — served from a standby before (and without) any
    promotion, still bit-identical."""
    _, q, m = next(op for op in script if op[0] == "query")
    twin = _engine(graph, ref, k=2)
    want, _ = twin.query(q, probe_mode=m)
    eng = _engine(graph, ref, k=2, failover="route")
    plan = FaultPlan([FaultSpec(kind=TIMEOUT, hook=HOOK_READ, at=1,
                                times=2)], seed=2)
    eng.set_fault_plan(plan)
    got, tel = eng.query(q, probe_mode=m,
                         budget=QueryBudget(hedge_after_ms=5.0))
    eng.set_fault_plan(None)
    assert got == want
    assert tel.outcome.hedges >= 1
    assert tel.outcome.served_degraded           # the hedge IS a standby read
    assert eng.router.standby_reads >= 1


def test_deadline_budget_raises_typed_mid_read(graph, ref, script):
    """A hard timeout_ms breach mid-read raises QueryDeadlineExceeded
    (typed, engine fully-old); the same query then succeeds fault-free."""
    _, q, m = next(op for op in script if op[0] == "query")
    eng = _engine(graph, ref, k=2, failover="route")
    plan = FaultPlan([FaultSpec(kind=TIMEOUT, hook=HOOK_READ, at=1,
                                times=3)], seed=3)
    eng.set_fault_plan(plan)
    with pytest.raises(QueryDeadlineExceeded) as exc:
        eng.query(q, probe_mode=m,
                  budget=QueryBudget(timeout_ms=10.0, hedge_after_ms=1e9))
    eng.set_fault_plan(None)
    assert exc.value.budget_ms == 10.0
    assert exc.value.spent_ms > 10.0
    want, _ = _engine(graph, ref, k=2).query(q, probe_mode=m)
    got, tel = eng.query(q, probe_mode=m)
    assert got == want and not tel.outcome.deadline_exceeded


def test_routed_read_exhaustion_is_typed(graph, ref, script):
    """Every attempt of the read budget lost -> TransferTimeoutError
    (never a silent partial answer)."""
    _, q, m = next(op for op in script if op[0] == "query")
    eng = _engine(graph, ref, k=2, failover="route")
    plan = FaultPlan([FaultSpec(kind=TIMEOUT, hook=HOOK_READ, at=1,
                                times=QueryBudget().max_attempts)], seed=4)
    eng.set_fault_plan(plan)
    with pytest.raises(TransferTimeoutError):
        eng.query(q, probe_mode=m,
                  budget=QueryBudget(hedge_after_ms=1e9))
    eng.set_fault_plan(None)


def test_brownout_sheds_low_priority_queries_typed(graph, ref, script):
    """Two crashes inside the fault window trip BROWNOUT: queries below
    the priority floor are shed with a typed AdmissionRejected; default-
    priority queries keep flowing with exact answers; recover()
    un-latches the state machine back to HEALTHY."""
    _, q, m = next(op for op in script if op[0] == "query")
    twin = _engine(graph, ref, k=2)
    want, _ = twin.query(q, probe_mode=m)
    eng = _engine(graph, ref, k=2, failover="route")
    eng.handle_machine_failure(0)
    eng.handle_machine_failure(1)
    assert eng.router.state() == BROWNOUT
    with pytest.raises(AdmissionRejected) as exc:
        eng.query(q, probe_mode=m, budget=QueryBudget(priority=0))
    assert exc.value.state == BROWNOUT
    assert exc.value.priority == 0
    assert eng.router.shed_queries == 1
    got, tel = eng.query(q, probe_mode=m)        # floor priority: served
    assert got == want
    assert tel.outcome.health == BROWNOUT
    assert tel.outcome.served_degraded
    rec = eng.recover()
    assert rec["lost"] == [] and rec["state"] == HEALTHY
    assert eng.router.state() == HEALTHY
    got2, tel2 = eng.query(q, probe_mode=m)
    assert got2 == want and tel2.outcome.health == HEALTHY


def test_route_mode_lost_shard_degrades_per_query_not_latched(graph, ref,
                                                              script):
    """Losing a shard's LAST copy in route mode does not latch the
    engine: only queries needing that shard raise (structured sids), the
    rest keep serving, and recover() reports the loss."""
    queries = [op for op in script if op[0] == "query"]
    eng = _engine(graph, ref, k=1, failover="route")
    victim_sid = min(sid for sid, mk in eng.routing.items() if mk == 0)
    eng.replicas.drop_shard(victim_sid)          # the standby rotted
    eng.handle_machine_failure(0)                # no raise: deferred
    assert eng._unavailable is None
    assert eng.router.lost_sids() == [victim_sid]
    assert eng.router.state() == BROWNOUT
    hits = fails = 0
    for _, q, m in queries:
        try:
            eng.query(q, probe_mode=m)
            hits += 1
        except ClusterUnavailableError as exc:
            assert exc.reason == "no-live-copy"
            assert victim_sid in exc.sids
            fails += 1
    assert fails > 0                             # some query needed it
    rec = eng.recover()
    assert rec["lost"] == [victim_sid]
    assert eng.router.state() == BROWNOUT        # loss persists, typed


# ------------------------------------------------------------------------- #
# the availability oracle: every schedule with a live copy gets the answer
# ------------------------------------------------------------------------- #

def _read_storm(seed):
    """Deterministic flaky-read overlay: CORRUPT/TIMEOUT/SLOW at the
    router.read hook, times < max_attempts so no read exhausts."""
    rng = np.random.default_rng(seed * 131 + 7)
    kinds = (CORRUPT, TIMEOUT, SLOW)
    return [FaultSpec(kind=kinds[int(rng.integers(3))], hook=HOOK_READ,
                      at=int(rng.integers(1, 40)), times=1,
                      factor=float(2.0 + 5.0 * rng.random()))
            for _ in range(int(rng.integers(1, 4)))]


def _avail_hand_schedules():
    mk = FaultSpec
    return [
        ("route-crash-query", [mk(kind=CRASH, hook=HOOK_QUERY, at=2,
                                  machine=1)]),
        ("route-crash-two", [mk(kind=CRASH, hook=HOOK_QUERY, at=2,
                                machine=0),
                             mk(kind=CRASH, hook=HOOK_BATCH, at=1,
                                machine=2)]),
        ("route-crash-mid-megabatch", [mk(kind=CRASH, hook=HOOK_BATCH,
                                          at=1, machine=2)]),
        ("route-crash-mid-update", [mk(kind=CRASH, hook=HOOK_UPDATE_STAGE,
                                       at=1, machine=0)]),
        ("route-crash-rebalance", [mk(kind=CRASH, hook=HOOK_REBALANCE,
                                      at=1, machine=1)]),
        ("route-read-flakes", [mk(kind=TIMEOUT, hook=HOOK_READ, at=2),
                               mk(kind=CORRUPT, hook=HOOK_READ, at=7),
                               mk(kind=SLOW, hook=HOOK_READ, at=11,
                                  factor=20.0)]),
        ("route-crash-plus-read-storm", [mk(kind=CRASH, hook=HOOK_QUERY,
                                            at=3, machine=0),
                                         mk(kind=TIMEOUT, hook=HOOK_READ,
                                            at=4),
                                         mk(kind=TIMEOUT, hook=HOOK_READ,
                                            at=9)]),
        ("route-link-storm", [mk(kind=TORN, hook=HOOK_TRANSFER, at=1,
                                 times=2),
                              mk(kind=CORRUPT, hook=HOOK_TRANSFER, at=4),
                              mk(kind=TIMEOUT, hook=HOOK_READ, at=3)]),
    ]


AVAIL_CASES = ([(name, FaultPlan(faults, seed=50 + i))
                for i, (name, faults) in enumerate(_avail_hand_schedules())]
               + [(f"avail-random-{s}",
                   FaultPlan(random_fault_plan(
                       100 + s, n_faults=4,
                       n_machines=N_MACHINES).faults
                       + tuple(_read_storm(s)), seed=100 + s))
                  for s in range(24)])
assert len(AVAIL_CASES) >= 30


@pytest.mark.parametrize("name,plan", AVAIL_CASES,
                         ids=[c[0] for c in AVAIL_CASES])
def test_availability_oracle_live_copy_schedules_always_answer(
        graph, ref, script, baseline, name, plan):
    """Tentpole oracle: k=2 on 3 machines with <= 2 crashes leaves every
    shard >= 1 live CRC-verified copy, so EVERY query of EVERY schedule
    must return the bit-identical answer — no ClusterUnavailableError,
    no Unavailable slot, no silent drop.  Strictly stronger than the
    PR-8 contract (never wrong): never wrong AND always answered."""
    eng = _engine(graph, ref, k=2, failover="route")
    answers, outcome = run_script(eng, script, plan=plan.replay(),
                                  on_unavailable="continue")
    assert outcome == "completed", f"{name}: {outcome}"
    lost = [i for i, a in enumerate(answers) if isinstance(a, Unavailable)]
    assert not lost, f"{name}: typed losses at {lost} with live copies"
    assert answers == baseline, f"{name}: answers diverged"


@pytest.mark.parametrize("seed", [0, 1])
def test_availability_oracle_quorum_loss_is_structured(graph, ref, script,
                                                       baseline, seed):
    """Quorum-loss schedules in continue mode: queries over genuinely
    lost shards yield structured Unavailable slots (reason + sids), all
    other answers stay bit-identical to the fault-free baseline."""
    plan = FaultPlan([FaultSpec(kind=CRASH, hook=HOOK_QUERY, at=2 + i,
                                machine=(seed + i) % N_MACHINES)
                     for i in range(N_MACHINES)], seed=seed)
    eng = _engine(graph, ref, k=1, failover="route")
    answers, outcome = run_script(eng, script, plan=plan,
                                  on_unavailable="continue")
    slots = [a for a in answers if isinstance(a, Unavailable)]
    if outcome == "completed" and not slots:
        assert answers == baseline
        return
    for a in slots:
        assert a.reason in ("no-live-copy", "no-survivors")
        assert a.reason != "no-live-copy" or a.sids
        lost = set(eng.router.lost_sids())
        assert set(a.sids) <= lost or not lost
    good = [(i, a) for i, a in enumerate(answers)
            if not isinstance(a, Unavailable)]
    for i, a in good:
        assert a == baseline[i], f"answer {i} diverged"


_DEAD_SUBSETS = [(0,), (1,), (2,), (0, 1), (0, 2), (1, 2)]


@given(dead=st.sampled_from(_DEAD_SUBSETS))
@settings(max_examples=len(_DEAD_SUBSETS), deadline=None)
def test_cross_mode_bit_identity_under_any_live_subset(graph, ref, script,
                                                       dead):
    """Property (satellite): for EVERY dead-machine subset that leaves
    >= 1 live copy of each shard (k=2 guarantees all subsets of size
    <= 2 do), the routed answers AND the deterministic counters AND the
    comm bytes are bit-identical across host / device / plane /
    megabatch execution."""
    counters = ("n_matches", "comm_bytes", "cross_shard_rows",
                "shards_skipped", "paths_executed", "paths_skipped")
    queries = [op[1] for op in script if op[0] == "query"][:2]
    eng = _engine(graph, ref, k=2, failover="route")
    eng.use_cache = False
    for mk in dead:
        eng.handle_machine_failure(mk)
    assert eng.router.lost_sids() == []
    ref_runs = []
    for q in queries:
        m0, t0 = eng.query(q, probe_mode="host")
        ref_runs.append((m0, t0))
    for mode in ("device", "plane"):
        for q, (m0, t0) in zip(queries, ref_runs):
            m1, t1 = eng.query(q, probe_mode=mode)
            assert m1 == m0
            for f in counters:
                assert getattr(t1, f) == getattr(t0, f), (mode, f)
    for (m2, t2), (m0, t0) in zip(eng.query_batch(queries), ref_runs):
        assert m2 == m0
        for f in counters:
            assert getattr(t2, f) == getattr(t0, f), ("megabatch", f)
