"""End-to-end system behaviour: the paper's full pipeline on one box.

Builds the distributed engine on a synthetic NWS graph, runs a mixed
workload with all three innovations active, and asserts the headline
properties: exactness, cache effectiveness, balancer activity, and
non-interruptible migration under fault injection.
"""

import numpy as np
import pytest

from repro.data.synthetic import make_dataset, make_workload, nws_graph
from repro.dist.cluster import DistributedGNNPE
from tests.conftest import vf2_oracle


@pytest.fixture(scope="module")
def system():
    g = nws_graph(500, 6, 0.1, 6, seed=1)
    eng = DistributedGNNPE.build(g, n_machines=4, shards_per_machine=3,
                                 gnn_train_steps=20, seed=1)
    return g, eng


def test_full_pipeline_exact_and_cached(system):
    g, eng = system
    queries = make_workload(g, 14, seed=2, hot_fraction=0.6, n_hot=3)
    tels = eng.run_workload(queries, rebalance=True, corrupt_prob=0.1)
    # exactness on a sample (oracle is expensive)
    for q in queries[:3]:
        matches, _ = eng.query(q)
        assert set(matches) == vf2_oracle(g, q)
    # the hot workload must produce cache hits
    assert sum(t.cache_hits for t in tels) > 0
    assert eng.cache.hit_rate > 0.1
    # telemetry sane
    assert all(t.latency_ms >= 0 for t in tels)
    assert any(t.shards_skipped > 0 for t in tels), \
        "root-MBR skip should prune some shards"


def test_offline_report_contract(system):
    _, eng = system
    rep = eng.offline_report
    assert rep["n_shards"] == 12
    assert rep["alloc_imbalance"] < 0.5
    assert len(rep["train_alloc"]) == 4


def test_migration_during_queries_no_interruption(system):
    """Queries issued while a migration batch is in flight stay exact."""
    g, eng = system
    queries = make_workload(g, 4, seed=7)
    sid = next(iter(eng.shards))
    from repro.dist.migration import hot_migrate
    src = eng.routing[sid]
    tgt = (src + 1) % 4
    res = hot_migrate(eng.shards, [(sid, src, tgt)], eng.routing,
                      rng=np.random.default_rng(1), corrupt_prob=0.5)
    assert res.crc_ok
    for q in queries:
        matches, _ = eng.query(q)
        assert set(matches) == vf2_oracle(g, q)


def test_dataset_presets():
    g = make_dataset("dblp-s")
    assert g.n_vertices == 2000 and g.n_edges > 1000


def test_query_plan_modes_agree(system):
    """All plan orders must give the same exact answer set."""
    g, eng = system
    q = make_workload(g, 1, seed=13)[0]
    eng.use_cache = False
    try:
        a, _ = eng.query(q, plan_mode="pescore")
        b, _ = eng.query(q, plan_mode="degree")
        c, _ = eng.query(q, plan_mode="natural")
    finally:
        eng.use_cache = True
    assert set(a) == set(b) == set(c)
