"""Core GNN-PE invariants: dominance certificate, aR-tree, matching, paths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import gnn as gnn_lib
from repro.core.artree import build_artree, query_dominating, query_stats
from repro.core.embedding import embed_query_paths, train_dominance_gnn
from repro.core.graph import LabeledGraph
from repro.core.matching import (backtrack_join, build_shard_index,
                                 exact_match, vertex_candidates)
from repro.core.paths import enumerate_paths, paths_of_query
from tests.conftest import vf2_oracle


def _random_graph(rng, n, m, n_labels):
    edges = rng.integers(0, n, size=(m, 2))
    return LabeledGraph.from_edges(n, edges, rng.integers(0, n_labels, n))


def _connected_subset(g, size, rng):
    v0 = int(rng.integers(g.n_vertices))
    vs = {v0}
    for _ in range(20 * size):
        if len(vs) >= size:
            break
        frontier = [u for v in vs for u in g.neighbors(v).tolist()
                    if u not in vs]
        if not frontier:
            break
        vs.add(int(rng.choice(frontier)))
    return np.array(sorted(vs))


# --------------------------------------------------------------------------- #
# dominance certificate: holds for ANY params, by construction
# --------------------------------------------------------------------------- #
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_dominance_certificate_any_params(seed):
    rng = np.random.default_rng(seed)
    g = _random_graph(rng, 60, 150, 4)
    if g.n_edges < 5:
        return
    cfg = gnn_lib.GNNConfig(n_labels=4)
    params = gnn_lib.init_params(cfg, jax.random.PRNGKey(seed))

    vids = _connected_subset(g, 5, rng)
    q, old = g.induced_subgraph(vids)
    if q.n_edges == 0:
        return
    # identity embedding: query vertex i == data vertex old[i]
    for table in paths_of_query(q, 2):
        q_emb = embed_query_paths(q, params, cfg, table)
        src = jnp.asarray(np.repeat(np.arange(g.n_vertices),
                                    np.diff(g.indptr)))
        dst = jnp.asarray(g.indices.astype(np.int64))
        mapped = old[table.vertices]
        d_emb = np.asarray(gnn_lib.encode_paths(
            params, cfg, jnp.asarray(g.labels), jnp.asarray(g.degrees),
            src, dst, jnp.asarray(mapped)))
        assert (q_emb <= d_emb + 1e-4).all(), \
            "dominance certificate violated for a true match"


# --------------------------------------------------------------------------- #
# aR-tree
# --------------------------------------------------------------------------- #
@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 400), d=st.integers(2, 12), seed=st.integers(0, 99))
def test_artree_exact_vs_bruteforce(n, d, seed):
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0, 1, size=(n, d)).astype(np.float32)
    tree = build_artree(pts, branching=8)
    q = rng.uniform(0, 1, size=d).astype(np.float32)
    got, _ = query_dominating(tree, q)
    want = np.flatnonzero((q[None, :] <= pts + 1e-5).all(axis=1))
    assert set(got.tolist()) == set(want.tolist())


def test_artree_serialize_roundtrip():
    rng = np.random.default_rng(0)
    pts = rng.uniform(0, 1, size=(100, 6)).astype(np.float32)
    tree = build_artree(pts)
    from repro.core.artree import ARTree
    t2 = ARTree.deserialize(tree.serialize())
    q = rng.uniform(0, 1, size=6).astype(np.float32)
    a, _ = query_dominating(tree, q)
    b, _ = query_dominating(t2, q)
    assert (np.sort(a) == np.sort(b)).all()
    assert tree.serialize() == t2.serialize()


def test_artree_aggregate_counts():
    rng = np.random.default_rng(1)
    pts = rng.uniform(0, 1, size=(137, 4)).astype(np.float32)
    tree = build_artree(pts, branching=4)
    assert int(tree.counts[0].sum()) == 137        # root level aggregates


# --------------------------------------------------------------------------- #
# path enumeration
# --------------------------------------------------------------------------- #
def test_enumerate_paths_simple_and_canonical(small_graph):
    t = enumerate_paths(small_graph, 2)
    v = t.vertices
    assert (v[:, 0] != v[:, 1]).all() and (v[:, 1] != v[:, 2]).all() \
        and (v[:, 0] != v[:, 2]).all(), "non-simple path"
    assert (v[:, 0] < v[:, -1]).all(), "canonical orientation violated"
    # every edge is a length-1 path
    t1 = enumerate_paths(small_graph, 1)
    assert t1.n_paths == small_graph.n_edges


# --------------------------------------------------------------------------- #
# zero-candidate early-exit (dominance proof of unmatchability)
# --------------------------------------------------------------------------- #
def test_zero_candidate_path_empties_vertex_sets():
    """Regression: a path with ZERO aR-tree candidates proves the query
    unmatchable, but `vertex_candidates` used to skip the intersection
    for empty arrays — the masks stayed label-filtered and the full
    backtracking join still ran.  Empty candidates must empty the
    path's vertex sets (the cluster engine's `alive` early-exit), so
    the join short-circuits without exploring anything."""
    # data: labels 0 and 1 both exist, but never adjacent
    data = LabeledGraph.from_edges(
        4, np.array([[0, 2], [1, 3]]), np.array([0, 1, 0, 1]))
    query = LabeledGraph.from_edges(
        2, np.array([[0, 1]]), np.array([0, 1]))
    q_tables = paths_of_query(query, 1)
    assert sum(t.n_paths for t in q_tables) == 1
    empty = [[np.zeros((0, t.length + 1), np.int32)
              for _ in range(t.n_paths)] for t in q_tables]
    cands = vertex_candidates(query, data, q_tables, empty)
    # label filter alone admits candidates; the zero-candidate path must
    # still empty every touched vertex set
    assert all(int(c.sum()) == 0 for c in cands), \
        "zero-candidate path must empty its vertex sets"
    assert backtrack_join(query, data, cands) == []


def test_zero_candidate_skips_remaining_paths():
    """Once one vertex set goes empty, later paths are not intersected
    (their masks keep the label-filter values) — mirrors cluster.query."""
    data = LabeledGraph.from_edges(
        4, np.array([[0, 2], [1, 3]]), np.array([0, 1, 0, 1]))
    # triangle-free query over two edges 0-1, 1-2
    query = LabeledGraph.from_edges(
        3, np.array([[0, 1], [1, 2]]), np.array([0, 1, 0]))
    q_tables = paths_of_query(query, 1)
    rows = [[np.zeros((0, t.length + 1), np.int32)
             for _ in range(t.n_paths)] for t in q_tables]
    cands = vertex_candidates(query, data, q_tables, rows)
    assert any(int(c.sum()) == 0 for c in cands)
    assert backtrack_join(query, data, cands) == []


def test_partial_plan_does_not_false_dismiss():
    """A plan that omits path rows must treat them as 'not probed' (no
    constraint), never as 'probed and provably empty' — a partial plan
    still returns the exact match set."""
    rng = np.random.default_rng(3)
    g = _random_graph(rng, 40, 120, 3)
    cfg = gnn_lib.GNNConfig(n_labels=3)
    params = gnn_lib.init_params(cfg, jax.random.PRNGKey(3))
    index = build_shard_index(g, params, cfg, max_length=2)
    q = None
    for seed in range(10):
        from repro.data.synthetic import random_walk_query
        cand = random_walk_query(g, 3, seed=seed)
        if sum(t.n_paths for t in paths_of_query(cand, 2)) >= 2:
            q = cand
            break
    assert q is not None
    full, _ = exact_match(q, g, index, params, cfg)
    partial, _ = exact_match(q, g, index, params, cfg, plan=[(0, 0)])
    assert set(partial) == set(full) == vf2_oracle(g, q)


# --------------------------------------------------------------------------- #
# end-to-end exactness vs VF2
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_exact_match_vs_vf2(nws_small, seed):
    from repro.data.synthetic import random_walk_query
    g = nws_small
    cfg = gnn_lib.GNNConfig(n_labels=g.n_labels)
    params = train_dominance_gnn(g, cfg, n_steps=20, seed=seed)
    index = build_shard_index(g, params, cfg, max_length=2)
    q = random_walk_query(g, 4, seed=seed)
    matches, stats = exact_match(q, g, index, params, cfg)
    assert set(matches) == vf2_oracle(g, q)
    assert stats.pruning_rate > 0.5, "index should prune most candidates"


def test_pruning_power_after_training(nws_small):
    """Training should not break exactness and should give high pruning."""
    g = nws_small
    cfg = gnn_lib.GNNConfig(n_labels=g.n_labels)
    params = train_dominance_gnn(g, cfg, n_steps=60, seed=0)
    index = build_shard_index(g, params, cfg, max_length=2)
    tree = index.trees[2]
    ep = index.embedded[2]
    rates = [query_stats(tree, ep.embeddings[i])["selectivity"]
             for i in range(0, min(ep.n_paths, 50), 5)]
    assert np.mean(rates) > 0.8
